//! Prefetching batch loader — the pipelining of Fig. 1 steps 2–4.
//!
//! A background thread materializes batches ahead of the consumer into a
//! bounded queue (double/triple buffering via `depth`), so data loading
//! and preparation hide behind GPU compute. `PrefetchLoader::next()` on
//! a warm queue is a channel pop — the exposed overhead the worker
//! profiler measures.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

/// One prepared mini-batch: feature payload + labels, both ready for
/// literal conversion in the runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    /// Global index of the first sample.
    pub start: u64,
    /// Flat f32 features (image models) — empty for token models.
    pub x_f32: Vec<f32>,
    /// Flat i32 features (token models) — empty for image models.
    pub x_i32: Vec<i32>,
    /// Labels/targets.
    pub y_i32: Vec<i32>,
}

/// Background prefetcher over any `FnMut(start, n) -> Batch` generator.
pub struct PrefetchLoader {
    rx: Option<Receiver<Batch>>,
    thread: Option<JoinHandle<()>>,
}

impl PrefetchLoader {
    /// Stream `total_batches` batches of `batch_size` starting at sample
    /// `start`, keeping up to `depth` batches queued.
    pub fn spawn<F>(
        mut make: F,
        start: u64,
        batch_size: usize,
        total_batches: usize,
        depth: usize,
    ) -> Self
    where
        F: FnMut(u64, usize) -> Batch + Send + 'static,
    {
        let (tx, rx) = sync_channel(depth.max(1));
        let thread = std::thread::spawn(move || {
            let mut cursor = start;
            for _ in 0..total_batches {
                let b = make(cursor, batch_size);
                cursor += batch_size as u64;
                if tx.send(b).is_err() {
                    return; // consumer dropped
                }
            }
        });
        PrefetchLoader { rx: Some(rx), thread: Some(thread) }
    }

    /// Next batch; `None` after `total_batches`.
    pub fn next(&mut self) -> Option<Batch> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }
}

impl Drop for PrefetchLoader {
    fn drop(&mut self) {
        // Close the channel FIRST: the producer's next send errors and
        // the thread exits. (Draining instead would race — the producer
        // can refill the bounded queue between the drain and the join
        // and block forever.)
        drop(self.rx.take());
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::ImageTask;
    use std::time::Duration;

    fn image_batcher(task: ImageTask) -> impl FnMut(u64, usize) -> Batch {
        move |start, n| {
            let (x, y) = task.batch(start, n);
            Batch { start, x_f32: x.into_vec(), x_i32: vec![], y_i32: y }
        }
    }

    #[test]
    fn yields_all_batches_in_order() {
        let task = ImageTask::cifar_like(1);
        let mut l = PrefetchLoader::spawn(image_batcher(task), 0, 4, 5, 2);
        let mut starts = Vec::new();
        while let Some(b) = l.next() {
            assert_eq!(b.x_f32.len(), 4 * 32 * 32 * 3);
            assert_eq!(b.y_i32.len(), 4);
            starts.push(b.start);
        }
        assert_eq!(starts, vec![0, 4, 8, 12, 16]);
    }

    #[test]
    fn prefetch_hides_slow_generation() {
        // Generator takes 5ms; with depth 2 the consumer's second read
        // should be near-instant because it was prefetched during the
        // consumer's simulated compute.
        let mut l = PrefetchLoader::spawn(
            |start, _n| {
                std::thread::sleep(Duration::from_millis(5));
                Batch { start, x_f32: vec![0.0], x_i32: vec![], y_i32: vec![0] }
            },
            0,
            1,
            4,
            2,
        );
        let _first = l.next().unwrap(); // pays generation latency
        std::thread::sleep(Duration::from_millis(20)); // "compute"
        let t0 = std::time::Instant::now();
        let _second = l.next().unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(3),
            "prefetched batch should pop instantly, took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn early_drop_terminates_producer() {
        let l = PrefetchLoader::spawn(
            |start, _| Batch { start, x_f32: vec![], x_i32: vec![], y_i32: vec![] },
            0,
            1,
            1_000_000,
            2,
        );
        drop(l); // must not hang
    }

    #[test]
    fn deterministic_given_task_seed() {
        let mk = |seed| {
            let task = ImageTask::cifar_like(seed);
            let mut l = PrefetchLoader::spawn(image_batcher(task), 0, 2, 2, 1);
            let mut out = Vec::new();
            while let Some(b) = l.next() {
                out.push(b);
            }
            out
        };
        assert_eq!(mk(5), mk(5));
    }
}
