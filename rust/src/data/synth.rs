//! Synthetic datasets — the ImageNet substitution (DESIGN.md §4).
//!
//! Requirements: deterministic from a seed, cheap to generate, and
//! *learnable* so convergence experiments (Fig. 3's error-vs-epoch
//! curves) are meaningful:
//! * [`ImageTask`] — each class is a fixed random spatial template;
//!   samples are the template plus noise and a random brightness shift.
//!   A CNN reaches low error quickly, and harder noise settings slow
//!   convergence the way harder datasets do.
//! * [`LmTask`] — byte sequences from a seeded order-1 Markov chain with
//!   skewed transitions; cross-entropy has a known-ish floor and drops
//!   as the model learns the transition table.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Class-conditional image classification task (NHWC f32 in [-1, 1]).
#[derive(Debug, Clone)]
pub struct ImageTask {
    pub size: usize,
    pub channels: usize,
    pub classes: usize,
    pub noise: f32,
    templates: Vec<Vec<f32>>,
}

impl ImageTask {
    pub fn new(size: usize, channels: usize, classes: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x1111_2222_3333_4444);
        let n = size * size * channels;
        let templates = (0..classes)
            .map(|_| (0..n).map(|_| rng.normal() as f32 * 0.7).collect())
            .collect();
        ImageTask { size, channels, classes, noise, templates }
    }

    /// The CNN artifact's task: 32x32x3, 10 classes.
    pub fn cifar_like(seed: u64) -> Self {
        ImageTask::new(32, 3, 10, 0.35, seed)
    }

    pub fn sample_bytes(&self) -> usize {
        self.size * self.size * self.channels * 4
    }

    /// Generate sample `index` deterministically: (image, label).
    pub fn sample(&self, index: u64) -> (Vec<f32>, i32) {
        let mut rng = Rng::new(index.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xABCD);
        let label = (rng.below(self.classes as u64)) as i32;
        let shift = rng.normal() as f32 * 0.2;
        let img = self.templates[label as usize]
            .iter()
            .map(|&t| (t + shift + rng.normal() as f32 * self.noise).clamp(-3.0, 3.0))
            .collect();
        (img, label)
    }

    /// Materialize a contiguous batch: (x: [n,h,w,c], y: [n]).
    pub fn batch(&self, start: u64, n: usize) -> (Tensor, Vec<i32>) {
        let mut xs = Vec::with_capacity(n * self.size * self.size * self.channels);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let (img, label) = self.sample(start + i as u64);
            xs.extend_from_slice(&img);
            ys.push(label);
        }
        (
            Tensor::from_vec(&[n, self.size, self.size, self.channels], xs),
            ys,
        )
    }
}

/// Order-1 Markov byte corpus for the LM artifacts.
#[derive(Debug, Clone)]
pub struct LmTask {
    pub vocab: usize,
    pub seq: usize,
    /// transition[c] = skewed distribution over next bytes (CDF).
    cdf: Vec<Vec<f64>>,
}

impl LmTask {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5555_AAAA);
        // Each state strongly prefers ~4 successors (low-entropy chain —
        // a model that learns it gets loss well under ln(vocab)).
        let cdf = (0..vocab)
            .map(|_| {
                let mut weights = vec![0.01f64; vocab];
                for _ in 0..4 {
                    let j = rng.below(vocab as u64) as usize;
                    weights[j] += 2.0 + rng.next_f64() * 4.0;
                }
                let total: f64 = weights.iter().sum();
                let mut acc = 0.0;
                weights
                    .iter()
                    .map(|w| {
                        acc += w / total;
                        acc
                    })
                    .collect()
            })
            .collect();
        LmTask { vocab, seq, cdf }
    }

    /// The LM artifact's task: byte vocab 256, seq 64.
    pub fn byte_level(seed: u64) -> Self {
        LmTask::new(256, 64, seed)
    }

    fn next_byte(&self, state: usize, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self.cdf[state].binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i.min(self.vocab - 1),
        }
    }

    /// Sequence `index`: (inputs[seq], targets[seq]) with targets = next
    /// byte (teacher forcing).
    pub fn sample(&self, index: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(index.wrapping_mul(0xD134_2543_DE82_EF95) ^ 0xEF01);
        let mut state = rng.below(self.vocab as u64) as usize;
        let mut bytes = Vec::with_capacity(self.seq + 1);
        bytes.push(state as i32);
        for _ in 0..self.seq {
            state = self.next_byte(state, &mut rng);
            bytes.push(state as i32);
        }
        (bytes[..self.seq].to_vec(), bytes[1..].to_vec())
    }

    /// Batch of token id tensors encoded as f32 bit-patterns is avoided:
    /// the runtime converts i32 directly; here we return raw id vectors.
    pub fn batch(&self, start: u64, n: usize) -> (Vec<i32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(n * self.seq);
        let mut ys = Vec::with_capacity(n * self.seq);
        for i in 0..n {
            let (x, y) = self.sample(start + i as u64);
            xs.extend_from_slice(&x);
            ys.extend_from_slice(&y);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_samples_deterministic() {
        let t = ImageTask::cifar_like(7);
        let (a, la) = t.sample(42);
        let (b, lb) = t.sample(42);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (c, _) = t.sample(43);
        assert_ne!(a, c);
    }

    #[test]
    fn image_labels_cover_classes() {
        let t = ImageTask::cifar_like(7);
        let mut seen = vec![false; t.classes];
        for i in 0..500 {
            let (_, l) = t.sample(i);
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "all classes should appear");
    }

    #[test]
    fn image_classes_are_separable() {
        // Nearest-template classification should beat chance by a lot —
        // otherwise Fig. 3 curves could never drop.
        let t = ImageTask::cifar_like(3);
        let mut correct = 0;
        let total = 200;
        for i in 0..total {
            let (img, label) = t.sample(i);
            let mut best = (f32::INFINITY, 0usize);
            for (c, tmpl) in t.templates.iter().enumerate() {
                let d: f32 = img
                    .iter()
                    .zip(tmpl)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == label as usize {
                correct += 1;
            }
        }
        assert!(
            correct as f64 / total as f64 > 0.8,
            "separability {correct}/{total}"
        );
    }

    #[test]
    fn batch_shapes() {
        let t = ImageTask::cifar_like(1);
        let (x, y) = t.batch(0, 8);
        assert_eq!(x.shape(), &[8, 32, 32, 3]);
        assert_eq!(y.len(), 8);
    }

    #[test]
    fn lm_deterministic_and_shifted() {
        let t = LmTask::byte_level(9);
        let (x, y) = t.sample(5);
        let (x2, _) = t.sample(5);
        assert_eq!(x, x2);
        assert_eq!(x.len(), 64);
        // Target is input shifted by one.
        assert_eq!(&x[1..], &y[..63]);
    }

    #[test]
    fn lm_chain_is_low_entropy() {
        // Empirical conditional entropy must sit well below ln(256):
        // that's what makes the LM loss curve fall.
        let t = LmTask::byte_level(2);
        let mut counts = std::collections::HashMap::new();
        let mut ctx_counts = std::collections::HashMap::new();
        for i in 0..400 {
            let (x, y) = t.sample(i);
            for (a, b) in x.iter().zip(&y) {
                *counts.entry((*a, *b)).or_insert(0u32) += 1;
                *ctx_counts.entry(*a).or_insert(0u32) += 1;
            }
        }
        let mut h = 0.0f64;
        let total: u32 = ctx_counts.values().sum();
        for ((a, _), &c) in &counts {
            let p_joint = c as f64 / total as f64;
            let p_cond = c as f64 / ctx_counts[a] as f64;
            h -= p_joint * p_cond.ln();
        }
        assert!(h < 3.0, "conditional entropy {h} should be far below ln256=5.55");
    }

    #[test]
    fn lm_tokens_in_vocab() {
        let t = LmTask::byte_level(4);
        let (xs, ys) = t.batch(0, 4);
        assert_eq!(xs.len(), 4 * 64);
        for v in xs.iter().chain(&ys) {
            assert!(*v >= 0 && (*v as usize) < t.vocab);
        }
    }
}
