//! Integer linear programming — the GLPK substitute the paper's §3.1.3
//! mini-batch optimization (Eq. 6) calls for.
//!
//! * [`simplex`]: dense two-phase primal simplex over standard-form LPs.
//! * [`branch_bound`]: exact 0/1 + general-integer branch-and-bound using
//!   the LP relaxation as the bound.
//!
//! Eq. 6 instances are tiny (layers × algorithms ≤ a few dozen binaries),
//! so an exact solver is both feasible and preferable to a heuristic.

pub mod branch_bound;
pub mod simplex;

pub use branch_bound::{solve_ilp, IlpStatus};
pub use simplex::{solve_lp, Constraint, LpProblem, LpStatus, Relation};
