//! Exact branch-and-bound over the simplex LP relaxation.
//!
//! Minimizes `c·x` with some variables constrained integer (the Eq. 6
//! instance is pure-binary: x_{k,l} ∈ {0,1}). Branching: most-fractional
//! variable; bounding: LP relaxation objective vs incumbent; depth-first
//! with best-bound tie-breaking is unnecessary at our sizes.

use super::simplex::{solve_lp, Constraint, LpProblem, LpStatus};

#[derive(Debug, Clone, PartialEq)]
pub enum IlpStatus {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
}

const INT_EPS: f64 = 1e-6;

/// Solve `p` with the variables in `integer_mask` required integral.
/// `upper_bounds[i]`, when finite, adds `x_i <= ub` (use 1.0 for 0/1).
pub fn solve_ilp(p: &LpProblem, integer_mask: &[bool], upper_bounds: &[f64]) -> IlpStatus {
    let n = p.objective.len();
    assert_eq!(integer_mask.len(), n);
    assert_eq!(upper_bounds.len(), n);

    let mut base = p.clone();
    for (i, &ub) in upper_bounds.iter().enumerate() {
        if ub.is_finite() {
            let mut row = vec![0.0; n];
            row[i] = 1.0;
            base.constraints.push(Constraint::le(row, ub));
        }
    }

    let mut best: Option<(Vec<f64>, f64)> = None;
    let mut stack = vec![base];
    let mut nodes = 0usize;

    while let Some(node) = stack.pop() {
        nodes += 1;
        if nodes > 200_000 {
            panic!("branch&bound node explosion ({nodes}); instance too big for exact solve");
        }
        let (x, obj) = match solve_lp(&node) {
            LpStatus::Optimal { x, objective } => (x, objective),
            LpStatus::Infeasible => continue,
            LpStatus::Unbounded => {
                // Integral restriction of an unbounded LP is unbounded or
                // infeasible; our advisor instances are bounded, so treat
                // as a modelling error.
                panic!("ILP relaxation unbounded — missing upper bounds?");
            }
        };
        // Bound: relaxation can't beat the incumbent.
        if let Some((_, inc)) = &best {
            if obj >= inc - 1e-9 {
                continue;
            }
        }
        // Find most-fractional integer variable.
        let mut frac_var: Option<(usize, f64)> = None;
        for i in 0..n {
            if integer_mask[i] {
                let f = x[i] - x[i].floor();
                let dist = (f - 0.5).abs();
                if f > INT_EPS && f < 1.0 - INT_EPS {
                    match frac_var {
                        None => frac_var = Some((i, dist)),
                        Some((_, bd)) if dist < bd => frac_var = Some((i, dist)),
                        _ => {}
                    }
                }
            }
        }
        match frac_var {
            None => {
                // Integral — candidate incumbent.
                let rounded: Vec<f64> = x
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| if integer_mask[i] { v.round() } else { v })
                    .collect();
                let better = best.as_ref().map_or(true, |(_, inc)| obj < inc - 1e-9);
                if better {
                    best = Some((rounded, obj));
                }
            }
            Some((i, _)) => {
                let floor = x[i].floor();
                // x_i <= floor branch
                let mut lo = node.clone();
                let mut row = vec![0.0; n];
                row[i] = 1.0;
                lo.constraints.push(Constraint::le(row.clone(), floor));
                // x_i >= floor + 1 branch
                let mut hi = node;
                hi.constraints.push(Constraint::ge(row, floor + 1.0));
                stack.push(lo);
                stack.push(hi);
            }
        }
    }

    match best {
        Some((x, objective)) => IlpStatus::Optimal { x, objective },
        None => IlpStatus::Infeasible,
    }
}

/// Convenience for pure 0/1 problems.
pub fn solve_binary(p: &LpProblem) -> IlpStatus {
    let n = p.objective.len();
    solve_ilp(p, &vec![true; n], &vec![1.0; n])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn knapsack_small() {
        // max 10a + 6b + 4c s.t. a+b+c<=2 (binary) → min form.
        let p = LpProblem {
            objective: vec![-10.0, -6.0, -4.0],
            constraints: vec![Constraint::le(vec![1.0, 1.0, 1.0], 2.0)],
        };
        match solve_binary(&p) {
            IlpStatus::Optimal { x, objective } => {
                assert!((objective + 16.0).abs() < 1e-6);
                assert!((x[0] - 1.0).abs() < 1e-6);
                assert!((x[1] - 1.0).abs() < 1e-6);
                assert!(x[2].abs() < 1e-6);
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn integrality_matters() {
        // LP relaxation picks x=2.5; ILP must pick an integer.
        // min -x s.t. 2x <= 5, x integer → x=2.
        let p = LpProblem {
            objective: vec![-1.0],
            constraints: vec![Constraint::le(vec![2.0], 5.0)],
        };
        match solve_ilp(&p, &[true], &[f64::INFINITY]) {
            IlpStatus::Optimal { x, objective } => {
                assert!((x[0] - 2.0).abs() < 1e-6);
                assert!((objective + 2.0).abs() < 1e-6);
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn infeasible_binary() {
        // a + b >= 3 with binaries is infeasible.
        let p = LpProblem {
            objective: vec![1.0, 1.0],
            constraints: vec![Constraint::ge(vec![1.0, 1.0], 3.0)],
        };
        assert_eq!(solve_binary(&p), IlpStatus::Infeasible);
    }

    #[test]
    fn assignment_constraint_like_eq6() {
        // Two layers x two algos; per-layer exactly-one; memory cap forces
        // the cheap algo on layer 2.
        // vars: x11 x12 x21 x22 ; times 5 2 7 3 ; mem 1 4 1 6 ; cap 6
        let p = LpProblem {
            objective: vec![5.0, 2.0, 7.0, 3.0],
            constraints: vec![
                Constraint::eq(vec![1.0, 1.0, 0.0, 0.0], 1.0),
                Constraint::eq(vec![0.0, 0.0, 1.0, 1.0], 1.0),
                Constraint::le(vec![1.0, 4.0, 1.0, 6.0], 6.0),
            ],
        };
        match solve_binary(&p) {
            IlpStatus::Optimal { x, objective } => {
                // best: x12 (t=2,m=4) + x21 (t=7,m=1) → t=9, m=5 <= 6
                assert!((objective - 9.0).abs() < 1e-6, "obj={objective} x={x:?}");
                assert!((x[1] - 1.0).abs() < 1e-6);
                assert!((x[2] - 1.0).abs() < 1e-6);
            }
            s => panic!("{s:?}"),
        }
    }

    /// Property: B&B matches exhaustive enumeration on random small
    /// binary knapsack-with-assignment instances (the Eq. 6 family).
    #[test]
    fn matches_bruteforce_random() {
        let mut rng = Rng::new(0xDEADBEEF);
        for _case in 0..60 {
            let layers = 1 + (rng.below(3) as usize);
            let algos = 2 + (rng.below(2) as usize);
            let n = layers * algos;
            let times: Vec<f64> = (0..n).map(|_| 1.0 + rng.next_f64() * 9.0).collect();
            let mems: Vec<f64> = (0..n).map(|_| 1.0 + rng.next_f64() * 9.0).collect();
            let cap = layers as f64 * (2.0 + rng.next_f64() * 6.0);

            let mut cons = Vec::new();
            for l in 0..layers {
                let mut row = vec![0.0; n];
                for a in 0..algos {
                    row[l * algos + a] = 1.0;
                }
                cons.push(Constraint::eq(row, 1.0));
            }
            cons.push(Constraint::le(mems.clone(), cap));
            let p = LpProblem {
                objective: times.clone(),
                constraints: cons,
            };

            // brute force
            let mut best: Option<f64> = None;
            let combos = (algos as u32).pow(layers as u32);
            for combo in 0..combos {
                let mut c = combo;
                let mut t = 0.0;
                let mut m = 0.0;
                for l in 0..layers {
                    let a = (c % algos as u32) as usize;
                    c /= algos as u32;
                    t += times[l * algos + a];
                    m += mems[l * algos + a];
                }
                if m <= cap + 1e-9 {
                    best = Some(best.map_or(t, |b: f64| b.min(t)));
                }
            }

            match (solve_binary(&p), best) {
                (IlpStatus::Optimal { objective, .. }, Some(b)) => {
                    assert!(
                        (objective - b).abs() < 1e-6,
                        "bb {objective} vs brute {b}"
                    );
                }
                (IlpStatus::Infeasible, None) => {}
                (got, want) => panic!("bb {got:?} vs brute {want:?}"),
            }
        }
    }
}
