//! Dense two-phase primal simplex.
//!
//! Problem form: minimize `c·x` subject to linear constraints
//! (`<=`, `>=`, `==`) and `x >= 0` (upper bounds are expressed as
//! constraints by the caller; `branch_bound` adds them during branching).
//!
//! Implementation: standard tableau simplex with Bland's rule (no
//! cycling), phase I artificial variables, phase II optimization.
//! Dense is fine — advisor instances have tens of variables.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Relation {
    Le,
    Ge,
    Eq,
}

#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<f64>,
    pub rel: Relation,
    pub rhs: f64,
}

impl Constraint {
    pub fn le(coeffs: Vec<f64>, rhs: f64) -> Self {
        Constraint { coeffs, rel: Relation::Le, rhs }
    }

    pub fn ge(coeffs: Vec<f64>, rhs: f64) -> Self {
        Constraint { coeffs, rel: Relation::Ge, rhs }
    }

    pub fn eq(coeffs: Vec<f64>, rhs: f64) -> Self {
        Constraint { coeffs, rel: Relation::Eq, rhs }
    }
}

/// minimize `objective · x` s.t. `constraints`, `x >= 0`.
#[derive(Debug, Clone, Default)]
pub struct LpProblem {
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum LpStatus {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    Unbounded,
}

const EPS: f64 = 1e-9;

/// Solve with two-phase tableau simplex.
pub fn solve_lp(p: &LpProblem) -> LpStatus {
    let n = p.objective.len();
    let m = p.constraints.len();
    for c in &p.constraints {
        assert_eq!(c.coeffs.len(), n, "constraint arity mismatch");
    }

    // Build standard form: every row gets rhs >= 0; slack/surplus columns
    // for Le/Ge; artificial columns for Ge/Eq rows (and Le rows whose rhs
    // flipped sign).
    #[derive(Clone, Copy, PartialEq)]
    enum Extra {
        Slack(usize),
        Artificial(usize),
    }
    let mut rows: Vec<(Vec<f64>, f64, Relation)> = Vec::with_capacity(m);
    for c in &p.constraints {
        let (mut coeffs, mut rhs, mut rel) = (c.coeffs.clone(), c.rhs, c.rel);
        if rhs < 0.0 {
            for a in &mut coeffs {
                *a = -*a;
            }
            rhs = -rhs;
            rel = match rel {
                Relation::Le => Relation::Ge,
                Relation::Ge => Relation::Le,
                Relation::Eq => Relation::Eq,
            };
        }
        rows.push((coeffs, rhs, rel));
    }

    let mut slack_cols = 0usize;
    let mut art_cols = 0usize;
    let mut row_extra: Vec<(Option<Extra>, Option<Extra>)> = Vec::with_capacity(m);
    for (_, _, rel) in &rows {
        match rel {
            Relation::Le => {
                row_extra.push((Some(Extra::Slack(slack_cols)), None));
                slack_cols += 1;
            }
            Relation::Ge => {
                row_extra.push((
                    Some(Extra::Slack(slack_cols)),
                    Some(Extra::Artificial(art_cols)),
                ));
                slack_cols += 1;
                art_cols += 1;
            }
            Relation::Eq => {
                row_extra.push((None, Some(Extra::Artificial(art_cols))));
                art_cols += 1;
            }
        }
    }

    let total = n + slack_cols + art_cols;
    // Tableau: m rows x (total + 1) columns (last = rhs).
    let mut t = vec![vec![0.0f64; total + 1]; m];
    let mut basis = vec![usize::MAX; m];
    for (i, (coeffs, rhs, rel)) in rows.iter().enumerate() {
        t[i][..n].copy_from_slice(coeffs);
        t[i][total] = *rhs;
        let (slack, art) = row_extra[i];
        if let Some(Extra::Slack(s)) = slack {
            let sign = if *rel == Relation::Ge { -1.0 } else { 1.0 };
            t[i][n + s] = sign;
            if *rel == Relation::Le {
                basis[i] = n + s;
            }
        }
        if let Some(Extra::Artificial(a)) = art {
            t[i][n + slack_cols + a] = 1.0;
            basis[i] = n + slack_cols + a;
        }
    }
    debug_assert!(basis.iter().all(|&b| b != usize::MAX));

    // ---- phase I: minimize sum of artificials -------------------------
    if art_cols > 0 {
        let mut obj = vec![0.0f64; total + 1];
        for a in 0..art_cols {
            obj[n + slack_cols + a] = 1.0;
        }
        // Price out basic artificials.
        let mut z = vec![0.0f64; total + 1];
        for (i, &b) in basis.iter().enumerate() {
            if b >= n + slack_cols {
                for j in 0..=total {
                    z[j] += t[i][j];
                }
            }
        }
        let reduced: Vec<f64> = (0..=total).map(|j| obj[j] - z[j]).collect();
        let mut red = reduced;
        if !pivot_loop(&mut t, &mut basis, &mut red, total) {
            return LpStatus::Unbounded; // cannot happen in phase I
        }
        let phase1_obj = -red[total];
        if phase1_obj > 1e-7 {
            return LpStatus::Infeasible;
        }
        // Drive any remaining basic artificials out of the basis.
        for i in 0..m {
            if basis[i] >= n + slack_cols {
                if let Some(j) = (0..n + slack_cols).find(|&j| t[i][j].abs() > EPS) {
                    pivot(&mut t, &mut red, i, j);
                    basis[i] = j;
                }
                // else: redundant row; harmless.
            }
        }
    }

    // ---- phase II: minimize the real objective ------------------------
    let mut obj = vec![0.0f64; total + 1];
    obj[..n].copy_from_slice(&p.objective);
    // Artificials must not re-enter: give them +inf-ish cost by exclusion
    // (we simply bar them in the pivot column choice via `limit`).
    let limit = n + slack_cols;
    let mut z = vec![0.0f64; total + 1];
    for (i, &b) in basis.iter().enumerate() {
        let cb = if b < n { p.objective[b] } else { 0.0 };
        if cb != 0.0 {
            for j in 0..=total {
                z[j] += cb * t[i][j];
            }
        }
    }
    let mut red: Vec<f64> = (0..=total).map(|j| obj[j] - z[j]).collect();
    if !pivot_loop_limited(&mut t, &mut basis, &mut red, total, limit) {
        return LpStatus::Unbounded;
    }

    let mut x = vec![0.0f64; n];
    for (i, &b) in basis.iter().enumerate() {
        if b < n {
            x[b] = t[i][total];
        }
    }
    let objective = p.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
    LpStatus::Optimal { x, objective }
}

fn pivot(t: &mut [Vec<f64>], red: &mut [f64], row: usize, col: usize) {
    let piv = t[row][col];
    debug_assert!(piv.abs() > EPS);
    let w = t[row].len();
    for j in 0..w {
        t[row][j] /= piv;
    }
    for i in 0..t.len() {
        if i != row && t[i][col].abs() > EPS {
            let f = t[i][col];
            for j in 0..w {
                t[i][j] -= f * t[row][j];
            }
        }
    }
    if red[col].abs() > EPS {
        let f = red[col];
        for j in 0..w {
            red[j] -= f * t[row][j];
        }
    }
}

fn pivot_loop(t: &mut [Vec<f64>], basis: &mut [usize], red: &mut [f64], total: usize) -> bool {
    pivot_loop_limited(t, basis, red, total, total)
}

/// Returns false on unboundedness. Bland's rule (least-index entering and
/// leaving) guarantees termination.
fn pivot_loop_limited(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    red: &mut [f64],
    total: usize,
    col_limit: usize,
) -> bool {
    let m = t.len();
    loop {
        // Entering column: first with negative reduced cost (Bland).
        let Some(col) = (0..col_limit.min(total)).find(|&j| red[j] < -EPS) else {
            return true; // optimal
        };
        // Leaving row: min ratio, ties by least basis index (Bland).
        let mut best: Option<(usize, f64)> = None;
        for i in 0..m {
            if t[i][col] > EPS {
                let ratio = t[i][total] / t[i][col];
                match best {
                    None => best = Some((i, ratio)),
                    Some((bi, br)) => {
                        if ratio < br - EPS || (ratio < br + EPS && basis[i] < basis[bi]) {
                            best = Some((i, ratio));
                        }
                    }
                }
            }
        }
        let Some((row, _)) = best else {
            return false; // unbounded
        };
        pivot(t, red, row, col);
        basis[row] = col;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opt(p: &LpProblem) -> (Vec<f64>, f64) {
        match solve_lp(p) {
            LpStatus::Optimal { x, objective } => (x, objective),
            s => panic!("expected optimal, got {s:?}"),
        }
    }

    #[test]
    fn basic_le() {
        // min -x - 2y s.t. x + y <= 4, x <= 2  → x=0, y=4, obj -8
        let p = LpProblem {
            objective: vec![-1.0, -2.0],
            constraints: vec![
                Constraint::le(vec![1.0, 1.0], 4.0),
                Constraint::le(vec![1.0, 0.0], 2.0),
            ],
        };
        let (x, obj) = opt(&p);
        assert!((obj + 8.0).abs() < 1e-6, "obj={obj}");
        assert!(x[0].abs() < 1e-6);
        assert!((x[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn ge_and_eq_need_phase1() {
        // min x + y s.t. x + y >= 3, x == 1 → y=2, obj 3
        let p = LpProblem {
            objective: vec![1.0, 1.0],
            constraints: vec![
                Constraint::ge(vec![1.0, 1.0], 3.0),
                Constraint::eq(vec![1.0, 0.0], 1.0),
            ],
        };
        let (x, obj) = opt(&p);
        assert!((obj - 3.0).abs() < 1e-6);
        assert!((x[0] - 1.0).abs() < 1e-6);
        assert!((x[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_detected() {
        let p = LpProblem {
            objective: vec![1.0],
            constraints: vec![
                Constraint::le(vec![1.0], 1.0),
                Constraint::ge(vec![1.0], 2.0),
            ],
        };
        assert_eq!(solve_lp(&p), LpStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x, x >= 0 unbounded below.
        let p = LpProblem {
            objective: vec![-1.0],
            constraints: vec![Constraint::ge(vec![1.0], 0.0)],
        };
        assert_eq!(solve_lp(&p), LpStatus::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // min x s.t. -x <= -2  (i.e. x >= 2)
        let p = LpProblem {
            objective: vec![1.0],
            constraints: vec![Constraint::le(vec![-1.0], -2.0)],
        };
        let (x, obj) = opt(&p);
        assert!((x[0] - 2.0).abs() < 1e-6);
        assert!((obj - 2.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_no_cycle() {
        // Klee-Minty-ish degenerate instance; Bland's rule must terminate.
        let p = LpProblem {
            objective: vec![-0.75, 150.0, -0.02, 6.0],
            constraints: vec![
                Constraint::le(vec![0.25, -60.0, -0.04, 9.0], 0.0),
                Constraint::le(vec![0.5, -90.0, -0.02, 3.0], 0.0),
                Constraint::le(vec![0.0, 0.0, 1.0, 0.0], 1.0),
            ],
        };
        let (_, obj) = opt(&p);
        assert!((obj + 0.05).abs() < 1e-6, "obj={obj}");
    }

    #[test]
    fn matches_bruteforce_on_grid() {
        // min c·x over box-and-sum constraints; compare with a fine grid.
        let p = LpProblem {
            objective: vec![2.0, 3.0],
            constraints: vec![
                Constraint::ge(vec![1.0, 2.0], 4.0),
                Constraint::le(vec![1.0, 1.0], 10.0),
            ],
        };
        let (_, obj) = opt(&p);
        let mut best = f64::INFINITY;
        let step = 0.01;
        let mut x0 = 0.0;
        while x0 <= 10.0 {
            let mut x1: f64 = 0.0;
            while x1 <= 10.0 {
                if x0 + 2.0 * x1 >= 4.0 - 1e-9 && x0 + x1 <= 10.0 + 1e-9 {
                    best = best.min(2.0 * x0 + 3.0 * x1);
                }
                x1 += step;
            }
            x0 += step;
        }
        assert!((obj - best).abs() < 0.05, "simplex {obj} vs grid {best}");
    }
}
