//! Deterministic fault injection for transports.
//!
//! [`FaultyTransport`] wraps any [`Transport`] (in-proc or TCP) and
//! injects failures — dropped, duplicated and truncated frames, added
//! latency, forced disconnects — according to a seeded [`FaultPlan`].
//! Every decision draws from a per-connection [`Rng`] stream forked
//! from `(plan.seed, conn)`, so a failing run replays *exactly* from
//! its seed: same ops fault, same frames truncate at the same byte,
//! same connection dies at the same op. Injected faults are recorded in
//! a shared [`FaultLog`] so chaos tests can assert bit-reproducibility
//! of the failure schedule itself.
//!
//! Fault semantics (client-side wrapper; the PS protocol is strictly
//! request/reply from the worker's perspective):
//! * **drop (send)** — the request frame vanishes; the *next* `recv`
//!   on this connection returns an injected error (modeling the reply
//!   timeout a real client would hit), so callers retry instead of
//!   blocking forever.
//! * **drop (recv)** — a reply frame is received and discarded; `recv`
//!   returns an injected error. The retry layer re-sends the request,
//!   which the server must deduplicate (the `(worker, step, seq)` tag).
//! * **dup (send)** — the request frame is sent twice. The wrapper
//!   swallows the extra reply on a later `recv`, keeping request/reply
//!   pairing in sync; the *server* must apply the duplicate
//!   idempotently.
//! * **trunc (send)** — a strict prefix of the frame is sent. The peer
//!   fails to decode and drops the connection (both transports surface
//!   this as errors, never hangs), exercising reconnect paths.
//! * **latency** — the op sleeps a seeded duration first (straggler
//!   injection; the schedule is deterministic even though wall time is
//!   not).
//! * **disconnect** — after `disconnect_after` ops every call on this
//!   connection errors (a dead peer / severed link).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::codec::Writer;
use super::message::Message;
use super::transport::Transport;
use crate::util::rng::Rng;

/// Prefix on every injected-fault error string, so retry layers and
/// tests can tell injected faults from real protocol errors.
pub const INJECTED: &str = "injected fault";

/// What a [`FaultyTransport`] did to one op.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    DropSend,
    DropRecv,
    DupSend,
    TruncSend,
    Disconnect,
    LatencyMs(u64),
}

/// One injected fault: connection id, per-connection op index, kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct FaultEvent {
    pub conn: u64,
    pub op: u64,
    pub kind: FaultKind,
}

/// Shared, thread-safe log of injected faults. Cloning shares the log.
#[derive(Debug, Clone, Default)]
pub struct FaultLog(Arc<Mutex<Vec<FaultEvent>>>);

impl FaultLog {
    pub fn new() -> Self {
        Self::default()
    }

    fn record(&self, conn: u64, op: u64, kind: FaultKind) {
        self.0.lock().unwrap().push(FaultEvent { conn, op, kind });
    }

    pub fn len(&self) -> usize {
        self.0.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events sorted by `(conn, op, kind)` — the deterministic view:
    /// global append order varies with thread scheduling, but the
    /// per-connection schedules are seeded, so the sorted log of two
    /// same-seed runs must be identical.
    pub fn snapshot_sorted(&self) -> Vec<FaultEvent> {
        let mut v = self.0.lock().unwrap().clone();
        v.sort_unstable();
        v
    }
}

/// Seeded fault schedule. Probabilities are per-op in `[0, 1]`; the
/// plan is `Copy`-cheap to clone and is shared by every connection of a
/// chaos run (each connection forks its own decision stream from
/// `(seed, conn)`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// P(outgoing frame dropped).
    pub drop_send: f64,
    /// P(incoming frame discarded after receipt).
    pub drop_recv: f64,
    /// P(outgoing frame duplicated).
    pub dup_send: f64,
    /// P(outgoing frame truncated to a strict prefix).
    pub trunc_send: f64,
    /// P(an op sleeps first); only meaningful with `latency_ms > 0`.
    pub latency_prob: f64,
    /// Upper bound on injected latency per faulted op, milliseconds.
    pub latency_ms: u64,
    /// Ops until the connection is severed for good (`None` = never).
    pub disconnect_after: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 1,
            drop_send: 0.0,
            drop_recv: 0.0,
            dup_send: 0.0,
            trunc_send: 0.0,
            latency_prob: 0.0,
            latency_ms: 0,
            disconnect_after: None,
        }
    }
}

impl FaultPlan {
    /// True when the plan injects nothing (wrapping is pointless).
    pub fn is_noop(&self) -> bool {
        self.drop_send == 0.0
            && self.drop_recv == 0.0
            && self.dup_send == 0.0
            && self.trunc_send == 0.0
            && (self.latency_prob == 0.0 || self.latency_ms == 0)
            && self.disconnect_after.is_none()
    }

    /// Parse a CLI spec: comma-separated `key=value` pairs. Keys:
    /// `seed`, `drop`, `recv_drop`, `dup`, `trunc`, `latency_p`,
    /// `latency_ms`, `disconnect_after`. Example:
    /// `seed=7,drop=0.05,dup=0.02,latency_ms=3,latency_p=0.5`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let Some((k, v)) = part.split_once('=') else {
                return Err(format!("fault-plan entry {part:?} is not key=value"));
            };
            let (k, v) = (k.trim(), v.trim());
            let prob = |v: &str| -> Result<f64, String> {
                let p: f64 = v
                    .parse()
                    .map_err(|e| format!("bad fault probability {v:?}: {e}"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("fault probability {p} outside [0, 1]"));
                }
                Ok(p)
            };
            match k {
                "seed" => plan.seed = v.parse().map_err(|e| format!("bad seed {v:?}: {e}"))?,
                "drop" => plan.drop_send = prob(v)?,
                "recv_drop" => plan.drop_recv = prob(v)?,
                "dup" => plan.dup_send = prob(v)?,
                "trunc" => plan.trunc_send = prob(v)?,
                "latency_p" => plan.latency_prob = prob(v)?,
                "latency_ms" => {
                    plan.latency_ms = v.parse().map_err(|e| format!("bad latency_ms {v:?}: {e}"))?
                }
                "disconnect_after" => {
                    plan.disconnect_after =
                        Some(v.parse().map_err(|e| format!("bad disconnect_after {v:?}: {e}"))?)
                }
                other => {
                    return Err(format!(
                        "unknown fault-plan key {other:?} \
                         (seed|drop|recv_drop|dup|trunc|latency_p|latency_ms|disconnect_after)"
                    ))
                }
            }
        }
        if plan.latency_prob > 0.0 && plan.latency_ms == 0 {
            plan.latency_ms = 1;
        }
        Ok(plan)
    }

    /// Wrap a transport. `conn` must be assigned deterministically by
    /// the caller (e.g. packed from worker id, server index, incarnation
    /// and reconnect attempt) — it seeds this connection's decision
    /// stream, so the same `(plan.seed, conn)` always replays the same
    /// faults.
    pub fn wrap(&self, conn: u64, log: FaultLog, inner: Box<dyn Transport>) -> FaultyTransport {
        FaultyTransport {
            inner,
            plan: self.clone(),
            rng: Rng::new(self.seed).fork(conn),
            conn,
            op: 0,
            scratch: Writer::with_capacity(256),
            pending_recv_error: None,
            extra_replies: 0,
            disconnected: false,
            log,
        }
    }
}

/// A [`Transport`] that injects seeded faults around an inner one.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    rng: Rng,
    conn: u64,
    /// Ops (send or recv calls) performed on this connection.
    op: u64,
    /// Reusable encode buffer: frames are staged here so drops,
    /// truncations and duplications act on the exact encoded bytes.
    scratch: Writer,
    /// Set when a send was dropped: the next recv fails (the "reply
    /// timeout" a real client would hit).
    pending_recv_error: Option<String>,
    /// Replies owed by duplicated requests, swallowed before the next
    /// real reply so request/reply pairing stays in sync.
    extra_replies: u32,
    disconnected: bool,
    log: FaultLog,
}

impl FaultyTransport {
    pub fn conn(&self) -> u64 {
        self.conn
    }

    pub fn op_count(&self) -> u64 {
        self.op
    }

    fn roll(&mut self, p: f64) -> bool {
        p > 0.0 && self.rng.next_f64() < p
    }

    /// Common per-op bookkeeping: disconnect schedule, then latency.
    fn begin_op(&mut self) -> Result<(), String> {
        if self.disconnected {
            return Err(format!("{INJECTED}: connection severed"));
        }
        self.op += 1;
        if let Some(n) = self.plan.disconnect_after {
            if self.op > n {
                self.disconnected = true;
                self.log.record(self.conn, self.op, FaultKind::Disconnect);
                return Err(format!("{INJECTED}: connection severed at op {}", self.op));
            }
        }
        let (p, cap) = (self.plan.latency_prob, self.plan.latency_ms);
        if cap > 0 && self.roll(p) {
            let ms = self.rng.below(cap) + 1;
            self.log.record(self.conn, self.op, FaultKind::LatencyMs(ms));
            std::thread::sleep(Duration::from_millis(ms));
        }
        Ok(())
    }

    fn faulty_send(&mut self, encode: &mut dyn FnMut(&mut Writer)) -> Result<(), String> {
        self.begin_op()?;
        let (drop_p, trunc_p, dup_p) =
            (self.plan.drop_send, self.plan.trunc_send, self.plan.dup_send);
        if self.roll(drop_p) {
            self.log.record(self.conn, self.op, FaultKind::DropSend);
            self.pending_recv_error = Some(format!("{INJECTED}: request frame dropped"));
            return Ok(());
        }
        self.scratch.clear();
        encode(&mut self.scratch);
        let trunc = if self.scratch.len() > 1 && self.roll(trunc_p) {
            Some(1 + self.rng.below(self.scratch.len() as u64 - 1) as usize)
        } else {
            None
        };
        let dup = trunc.is_none() && self.roll(dup_p);
        if trunc.is_some() {
            self.log.record(self.conn, self.op, FaultKind::TruncSend);
        } else if dup {
            self.log.record(self.conn, self.op, FaultKind::DupSend);
        }
        let FaultyTransport { inner, scratch, extra_replies, .. } = self;
        let bytes = scratch.as_bytes();
        if let Some(cut) = trunc {
            // A strict prefix: the peer's decode fails and it drops the
            // connection, which the next op here surfaces as an error.
            return inner.send_with(&mut |w| w.raw(&bytes[..cut]));
        }
        if dup {
            inner.send_with(&mut |w| w.raw(bytes))?;
            *extra_replies += 1;
        }
        inner.send_with(&mut |w| w.raw(bytes))
    }

    fn faulty_recv(
        &mut self,
        decode: &mut dyn FnMut(&[u8]) -> Result<(), String>,
    ) -> Result<(), String> {
        self.begin_op()?;
        if let Some(e) = self.pending_recv_error.take() {
            return Err(e);
        }
        // Replies owed to duplicated requests come first on the wire —
        // swallow them so the caller sees one reply per request.
        while self.extra_replies > 0 {
            self.extra_replies -= 1;
            self.inner.recv_with(&mut |_| Ok(()))?;
        }
        let p = self.plan.drop_recv;
        if self.roll(p) {
            self.log.record(self.conn, self.op, FaultKind::DropRecv);
            self.inner.recv_with(&mut |_| Ok(()))?;
            return Err(format!("{INJECTED}: reply frame dropped"));
        }
        self.inner.recv_with(decode)
    }
}

impl Transport for FaultyTransport {
    fn send(&mut self, msg: &Message) -> Result<(), String> {
        self.faulty_send(&mut |w| msg.encode_into(w))
    }

    fn recv(&mut self) -> Result<Message, String> {
        let mut msg = None;
        self.faulty_recv(&mut |frame| {
            msg = Some(Message::decode(frame)?);
            Ok(())
        })?;
        msg.ok_or_else(|| "recv_with yielded no frame".to_string())
    }

    fn send_with(&mut self, encode: &mut dyn FnMut(&mut Writer)) -> Result<(), String> {
        self.faulty_send(encode)
    }

    fn recv_with(
        &mut self,
        decode: &mut dyn FnMut(&[u8]) -> Result<(), String>,
    ) -> Result<(), String> {
        self.faulty_recv(decode)
    }

    fn set_read_deadline(&mut self, deadline: Option<Duration>) -> Result<(), String> {
        // Deadlines pass through untouched: injected faults model the
        // network, not the local socket configuration.
        self.inner.set_read_deadline(deadline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::InProcTransport;
    use std::thread;

    fn wrapped(plan: &FaultPlan, conn: u64) -> (FaultyTransport, InProcTransport, FaultLog) {
        let log = FaultLog::new();
        let (a, b) = InProcTransport::pair();
        (plan.wrap(conn, log.clone(), Box::new(a)), b, log)
    }

    #[test]
    fn parse_spec_roundtrip() {
        let p = FaultPlan::parse(
            "seed=7,drop=0.05,recv_drop=0.01,dup=0.02,trunc=0.03,latency_p=0.5,latency_ms=3,disconnect_after=40",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.drop_send, 0.05);
        assert_eq!(p.drop_recv, 0.01);
        assert_eq!(p.dup_send, 0.02);
        assert_eq!(p.trunc_send, 0.03);
        assert_eq!(p.latency_prob, 0.5);
        assert_eq!(p.latency_ms, 3);
        assert_eq!(p.disconnect_after, Some(40));
        assert!(!p.is_noop());
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert!(FaultPlan::parse("seed=3").unwrap().is_noop());
        assert!(FaultPlan::parse("drop=1.5").is_err());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("drop").is_err());
        // latency_p without latency_ms implies a 1 ms cap.
        assert_eq!(FaultPlan::parse("latency_p=1").unwrap().latency_ms, 1);
    }

    #[test]
    fn noop_plan_passes_frames_through() {
        let (mut a, mut b, log) = wrapped(&FaultPlan::default(), 0);
        a.send(&Message::Stats).unwrap();
        assert_eq!(b.recv().unwrap(), Message::Stats);
        b.send(&Message::PushAck { clock: 3 }).unwrap();
        assert_eq!(a.recv().unwrap(), Message::PushAck { clock: 3 });
        assert!(log.is_empty());
        assert_eq!(a.op_count(), 2);
    }

    #[test]
    fn dropped_send_fails_next_recv() {
        let plan = FaultPlan { drop_send: 1.0, ..Default::default() };
        let (mut a, mut b, log) = wrapped(&plan, 1);
        a.send(&Message::Stats).unwrap(); // silently dropped
        let err = a.recv().unwrap_err();
        assert!(err.contains(INJECTED), "{err}");
        assert_eq!(log.len(), 1);
        assert_eq!(log.snapshot_sorted()[0].kind, FaultKind::DropSend);
        // Nothing ever reached the peer.
        drop(a);
        assert!(b.recv().is_err());
    }

    #[test]
    fn dropped_recv_consumes_and_errors() {
        let plan = FaultPlan { drop_recv: 1.0, ..Default::default() };
        let (mut a, mut b, log) = wrapped(&plan, 2);
        b.send(&Message::PushAck { clock: 1 }).unwrap();
        let err = a.recv().unwrap_err();
        assert!(err.contains("reply frame dropped"), "{err}");
        assert_eq!(log.snapshot_sorted()[0].kind, FaultKind::DropRecv);
    }

    #[test]
    fn duplicated_request_reply_stays_in_sync() {
        // Echo peer: replies PushAck{clock = frames seen} per frame.
        let plan = FaultPlan { dup_send: 1.0, ..Default::default() };
        let (mut a, mut b, log) = wrapped(&plan, 3);
        let peer = thread::spawn(move || {
            let mut clock = 0;
            while b.recv().is_ok() {
                clock += 1;
                if b.send(&Message::PushAck { clock }).is_err() {
                    break;
                }
            }
            clock
        });
        // Two request/reply rounds; each request is duplicated, yet the
        // client sees exactly one (the latest pending) reply per round.
        a.send(&Message::Stats).unwrap();
        assert!(matches!(a.recv().unwrap(), Message::PushAck { .. }));
        a.send(&Message::Stats).unwrap();
        assert!(matches!(a.recv().unwrap(), Message::PushAck { .. }));
        drop(a);
        let frames_seen = peer.join().unwrap();
        assert_eq!(frames_seen, 4, "peer must have seen each request twice");
        assert_eq!(
            log.snapshot_sorted().iter().filter(|e| e.kind == FaultKind::DupSend).count(),
            2
        );
    }

    #[test]
    fn truncated_frame_poisons_peer_decode() {
        let plan = FaultPlan { trunc_send: 1.0, ..Default::default() };
        let (mut a, mut b, log) = wrapped(&plan, 4);
        a.send(&Message::Error { what: "long enough body".into() }).unwrap();
        assert!(b.recv().is_err(), "peer must fail to decode the prefix");
        assert_eq!(log.snapshot_sorted()[0].kind, FaultKind::TruncSend);
    }

    #[test]
    fn disconnect_after_severs_connection() {
        let plan = FaultPlan { disconnect_after: Some(2), ..Default::default() };
        let (mut a, mut b, log) = wrapped(&plan, 5);
        a.send(&Message::Stats).unwrap();
        b.send(&Message::PushAck { clock: 0 }).unwrap();
        a.recv().unwrap();
        let err = a.send(&Message::Stats).unwrap_err();
        assert!(err.contains("severed"), "{err}");
        // And it stays severed.
        assert!(a.recv().is_err());
        assert_eq!(
            log.snapshot_sorted().iter().filter(|e| e.kind == FaultKind::Disconnect).count(),
            1
        );
    }

    #[test]
    fn latency_logged_and_frame_still_delivered() {
        let plan = FaultPlan { latency_prob: 1.0, latency_ms: 1, ..Default::default() };
        let (mut a, mut b, log) = wrapped(&plan, 6);
        a.send(&Message::Stats).unwrap();
        assert_eq!(b.recv().unwrap(), Message::Stats);
        assert!(matches!(log.snapshot_sorted()[0].kind, FaultKind::LatencyMs(_)));
    }

    #[test]
    fn same_seed_same_conn_replays_identical_faults() {
        let plan = FaultPlan {
            seed: 99,
            drop_send: 0.3,
            dup_send: 0.3,
            drop_recv: 0.2,
            ..Default::default()
        };
        let run = || {
            let (mut a, mut b, log) = wrapped(&plan, 7);
            // Fixed op script; replies only matter when a recv happens.
            for _ in 0..30 {
                let _ = a.send(&Message::Stats);
                // Feed enough replies that a non-dropped recv never blocks.
                for _ in 0..3 {
                    let _ = b.send(&Message::PushAck { clock: 0 });
                }
                let _ = a.recv();
            }
            log.snapshot_sorted()
        };
        let first = run();
        let second = run();
        assert!(!first.is_empty(), "plan injected nothing in 60 ops");
        assert_eq!(first, second, "fault schedule must replay bit-identically");
        // A different connection id draws a different schedule.
        let (mut a, mut b, other_log) = wrapped(&plan, 8);
        for _ in 0..30 {
            let _ = a.send(&Message::Stats);
            for _ in 0..3 {
                let _ = b.send(&Message::PushAck { clock: 0 });
            }
            let _ = a.recv();
        }
        assert_ne!(first, other_log.snapshot_sorted());
    }
}
