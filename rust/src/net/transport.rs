//! Length-framed message transports.
//!
//! Frames are `u32 length || payload`. Two implementations:
//! * [`TcpTransport`] — blocking TCP with `TCP_NODELAY`, used by the
//!   real distributed deployment (one thread per connection).
//! * [`InProcTransport`] — mpsc channel pair for single-process clusters
//!   and tests (zero-copy, no serialization needed but kept symmetric by
//!   moving the encoded frame).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};

use super::message::Message;

/// Bidirectional message pipe.
pub trait Transport: Send {
    fn send(&mut self, msg: &Message) -> Result<(), String>;
    fn recv(&mut self) -> Result<Message, String>;
}

/// Hard cap on frame size (guards against corrupt length prefixes).
const MAX_FRAME: u32 = 1 << 30;

// ------------------------------------------------------------------ TCP

pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self, String> {
        stream
            .set_nodelay(true)
            .map_err(|e| format!("set_nodelay: {e}"))?;
        Ok(TcpTransport { stream })
    }

    pub fn peer(&self) -> String {
        self.stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into())
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Message) -> Result<(), String> {
        let body = msg.encode();
        let len = (body.len() as u32).to_le_bytes();
        // One write for header+body halves syscalls on small messages.
        let mut frame = Vec::with_capacity(4 + body.len());
        frame.extend_from_slice(&len);
        frame.extend_from_slice(&body);
        self.stream
            .write_all(&frame)
            .map_err(|e| format!("send: {e}"))
    }

    fn recv(&mut self) -> Result<Message, String> {
        let mut hdr = [0u8; 4];
        self.stream
            .read_exact(&mut hdr)
            .map_err(|e| format!("recv header: {e}"))?;
        let len = u32::from_le_bytes(hdr);
        if len > MAX_FRAME {
            return Err(format!("frame length {len} exceeds cap"));
        }
        let mut body = vec![0u8; len as usize];
        self.stream
            .read_exact(&mut body)
            .map_err(|e| format!("recv body: {e}"))?;
        Message::decode(&body)
    }
}

/// Connect to a server address.
pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<TcpTransport, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect: {e}"))?;
    TcpTransport::new(stream)
}

/// Bind a listener; the caller accepts in its own loop.
pub fn listen<A: ToSocketAddrs>(addr: A) -> Result<TcpListener, String> {
    TcpListener::bind(addr).map_err(|e| format!("bind: {e}"))
}

// ----------------------------------------------------------- in-process

/// Channel-backed transport; `pair()` yields two connected endpoints.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl InProcTransport {
    pub fn pair() -> (InProcTransport, InProcTransport) {
        let (atx, arx) = channel();
        let (btx, brx) = channel();
        (
            InProcTransport { tx: atx, rx: brx },
            InProcTransport { tx: btx, rx: arx },
        )
    }
}

impl Transport for InProcTransport {
    fn send(&mut self, msg: &Message) -> Result<(), String> {
        self.tx
            .send(msg.encode())
            .map_err(|_| "peer disconnected".to_string())
    }

    fn recv(&mut self) -> Result<Message, String> {
        let frame = self
            .rx
            .recv()
            .map_err(|_| "peer disconnected".to_string())?;
        Message::decode(&frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::thread;

    #[test]
    fn inproc_roundtrip() {
        let (mut a, mut b) = InProcTransport::pair();
        a.send(&Message::Stats).unwrap();
        assert_eq!(b.recv().unwrap(), Message::Stats);
        b.send(&Message::PushAck { clock: 5 }).unwrap();
        assert_eq!(a.recv().unwrap(), Message::PushAck { clock: 5 });
    }

    #[test]
    fn inproc_disconnect_detected() {
        let (mut a, b) = InProcTransport::pair();
        drop(b);
        assert!(a.send(&Message::Stats).is_err());
    }

    #[test]
    fn tcp_roundtrip_with_tensors() {
        let listener = listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap(); // echo
        });
        let mut c = connect(addr).unwrap();
        let msg = Message::Push {
            worker: 9,
            step: 3,
            entries: vec![(0, Tensor::from_vec(&[128], vec![0.25; 128]))],
        };
        c.send(&msg).unwrap();
        assert_eq!(c.recv().unwrap(), msg);
        server.join().unwrap();
    }

    #[test]
    fn tcp_many_messages_in_order() {
        let listener = listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            for i in 0..100u64 {
                match t.recv().unwrap() {
                    Message::Barrier { step, .. } => assert_eq!(step, i),
                    m => panic!("unexpected {m:?}"),
                }
            }
            t.send(&Message::BarrierRelease { step: 99 }).unwrap();
        });
        let mut c = connect(addr).unwrap();
        for i in 0..100u64 {
            c.send(&Message::Barrier { worker: 0, step: i }).unwrap();
        }
        assert_eq!(c.recv().unwrap(), Message::BarrierRelease { step: 99 });
        server.join().unwrap();
    }
}
