//! Length-framed message transports.
//!
//! Frames are `u32 length || payload`. Two implementations:
//! * [`TcpTransport`] — blocking TCP with `TCP_NODELAY`, used by the
//!   real distributed deployment (one thread per connection).
//! * [`InProcTransport`] — mpsc channel pair for single-process clusters
//!   and tests (zero-copy, no serialization needed but kept symmetric by
//!   moving the encoded frame).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};

use super::codec::Writer;
use super::message::Message;

/// Bidirectional message pipe.
pub trait Transport: Send {
    fn send(&mut self, msg: &Message) -> Result<(), String>;
    fn recv(&mut self) -> Result<Message, String>;

    /// Send a frame whose body the caller encodes in place.
    ///
    /// This is the zero-copy send path: `encode` writes the message body
    /// directly into the transport's frame buffer (for TCP, a persistent
    /// buffer already holding the length prefix), so hot-path senders
    /// can stream borrowed tensors without building an owned `Message`.
    fn send_with(&mut self, encode: &mut dyn FnMut(&mut Writer)) -> Result<(), String>;

    /// Receive one frame and hand its raw body to `decode` — the
    /// zero-copy receive path, symmetric with
    /// [`send_with`](Self::send_with). The closure borrows the
    /// transport's receive buffer, so streaming decoders (e.g.
    /// `net::message::wire::CompressedPushBody`) can apply entries
    /// without building an owned [`Message`].
    fn recv_with(
        &mut self,
        decode: &mut dyn FnMut(&[u8]) -> Result<(), String>,
    ) -> Result<(), String>;

    /// Bound every subsequent receive: a peer silent for longer than
    /// `deadline` surfaces as a retryable recv error instead of
    /// blocking forever — how a worker notices a wedged (gray-failed,
    /// promoted-away) server. `None` restores unbounded blocking.
    /// Default is a no-op for transports without timeout support.
    fn set_read_deadline(
        &mut self,
        deadline: Option<std::time::Duration>,
    ) -> Result<(), String> {
        let _ = deadline;
        Ok(())
    }
}

/// Hard cap on frame size (guards against corrupt length prefixes).
const MAX_FRAME: u32 = 1 << 30;

/// Persistent frame buffers keep their allocation across messages (the
/// hot path), but shrink back once capacity exceeds both this floor and
/// 4x the frame just processed — a single outlier frame must not pin
/// its memory for the connection's lifetime, while steady-state large
/// frames (whose size ≈ capacity) keep their buffer.
const BUF_RETAIN_CAP: usize = 1 << 20;

/// Single copy of the retention policy, shared by the send (`Writer`)
/// and receive (`Vec<u8>`) buffers.
fn buf_oversized(capacity: usize, last_frame: usize) -> bool {
    capacity > BUF_RETAIN_CAP && capacity > 4 * last_frame
}

// ------------------------------------------------------------------ TCP

pub struct TcpTransport {
    stream: TcpStream,
    /// Reusable send buffer holding `u32 len || body`; cleared (but not
    /// shrunk) per frame so steady-state sends do zero allocations.
    wbuf: Writer,
    /// Reusable receive buffer for frame bodies.
    rbuf: Vec<u8>,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self, String> {
        stream
            .set_nodelay(true)
            .map_err(|e| format!("set_nodelay: {e}"))?;
        Ok(TcpTransport {
            stream,
            wbuf: Writer::with_capacity(256),
            rbuf: Vec::new(),
        })
    }

    pub fn peer(&self) -> String {
        self.stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into())
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Message) -> Result<(), String> {
        self.send_with(&mut |w| msg.encode_into(w))
    }

    fn recv(&mut self) -> Result<Message, String> {
        let mut msg = None;
        self.recv_with(&mut |frame| {
            msg = Some(Message::decode(frame)?);
            Ok(())
        })?;
        msg.ok_or_else(|| "recv_with yielded no frame".to_string())
    }

    fn send_with(&mut self, encode: &mut dyn FnMut(&mut Writer)) -> Result<(), String> {
        // Header + body in one buffer and one write: the length prefix
        // is patched after the body lands, so small messages still cost
        // a single syscall and large ones a single memcpy-free encode.
        self.wbuf.clear();
        self.wbuf.u32(0); // length placeholder
        encode(&mut self.wbuf);
        let body_len = self.wbuf.len() - 4;
        if body_len as u64 > MAX_FRAME as u64 {
            return Err(format!("frame length {body_len} exceeds cap"));
        }
        self.wbuf.set_u32(0, body_len as u32);
        let sent = self
            .stream
            .write_all(self.wbuf.as_bytes())
            .map_err(|e| format!("send: {e}"));
        let frame_len = self.wbuf.len();
        if buf_oversized(self.wbuf.capacity(), frame_len) {
            self.wbuf.shrink_to(BUF_RETAIN_CAP.max(frame_len));
        }
        sent
    }

    fn recv_with(
        &mut self,
        decode: &mut dyn FnMut(&[u8]) -> Result<(), String>,
    ) -> Result<(), String> {
        let mut hdr = [0u8; 4];
        self.stream
            .read_exact(&mut hdr)
            .map_err(|e| format!("recv header: {e}"))?;
        let len = u32::from_le_bytes(hdr);
        if len > MAX_FRAME {
            return Err(format!("frame length {len} exceeds cap"));
        }
        self.rbuf.clear();
        self.rbuf.resize(len as usize, 0);
        self.stream
            .read_exact(&mut self.rbuf)
            .map_err(|e| format!("recv body: {e}"))?;
        let out = decode(&self.rbuf);
        if buf_oversized(self.rbuf.capacity(), len as usize) {
            self.rbuf.shrink_to(BUF_RETAIN_CAP.max(len as usize));
        }
        out
    }

    fn set_read_deadline(
        &mut self,
        deadline: Option<std::time::Duration>,
    ) -> Result<(), String> {
        // `set_read_timeout(Some(ZERO))` is an error by contract; treat
        // a zero deadline as the smallest representable one.
        let deadline = deadline.map(|d| d.max(std::time::Duration::from_millis(1)));
        self.stream
            .set_read_timeout(deadline)
            .map_err(|e| format!("set_read_timeout: {e}"))
    }
}

/// Connect to a server address.
pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<TcpTransport, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect: {e}"))?;
    TcpTransport::new(stream)
}

/// Connect with a bound on both the TCP handshake and every subsequent
/// read. The control plane's defense against wedged peers: a lease
/// prober must never block forever on the very failure it exists to
/// detect, so its probes time out and count as misses instead.
pub fn connect_timeout(
    addr: &std::net::SocketAddr,
    timeout: std::time::Duration,
) -> Result<TcpTransport, String> {
    let stream =
        TcpStream::connect_timeout(addr, timeout).map_err(|e| format!("connect: {e}"))?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| format!("set_read_timeout: {e}"))?;
    TcpTransport::new(stream)
}

/// Bind a listener; the caller accepts in its own loop.
pub fn listen<A: ToSocketAddrs>(addr: A) -> Result<TcpListener, String> {
    TcpListener::bind(addr).map_err(|e| format!("bind: {e}"))
}

// ----------------------------------------------------------- in-process

/// Channel-backed transport; `pair()` yields two connected endpoints.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    /// Mirrors [`Transport::set_read_deadline`] for channel receives.
    deadline: Option<std::time::Duration>,
}

impl InProcTransport {
    pub fn pair() -> (InProcTransport, InProcTransport) {
        let (atx, arx) = channel();
        let (btx, brx) = channel();
        (
            InProcTransport { tx: atx, rx: brx, deadline: None },
            InProcTransport { tx: btx, rx: arx, deadline: None },
        )
    }

    fn recv_frame(&self) -> Result<Vec<u8>, String> {
        match self.deadline {
            None => self.rx.recv().map_err(|_| "peer disconnected".to_string()),
            Some(d) => self.rx.recv_timeout(d).map_err(|e| match e {
                std::sync::mpsc::RecvTimeoutError::Timeout => {
                    format!("recv timed out after {d:?}")
                }
                std::sync::mpsc::RecvTimeoutError::Disconnected => {
                    "peer disconnected".to_string()
                }
            }),
        }
    }
}

impl Transport for InProcTransport {
    fn send(&mut self, msg: &Message) -> Result<(), String> {
        self.tx
            .send(msg.encode())
            .map_err(|_| "peer disconnected".to_string())
    }

    fn recv(&mut self) -> Result<Message, String> {
        let frame = self.recv_frame()?;
        Message::decode(&frame)
    }

    fn send_with(&mut self, encode: &mut dyn FnMut(&mut Writer)) -> Result<(), String> {
        // Channel frames are owned, so the encoded body is built fresh
        // and moved — still a single allocation, no tensor clones.
        let mut w = Writer::with_capacity(256);
        encode(&mut w);
        self.tx
            .send(w.finish())
            .map_err(|_| "peer disconnected".to_string())
    }

    fn recv_with(
        &mut self,
        decode: &mut dyn FnMut(&[u8]) -> Result<(), String>,
    ) -> Result<(), String> {
        let frame = self.recv_frame()?;
        decode(&frame)
    }

    fn set_read_deadline(
        &mut self,
        deadline: Option<std::time::Duration>,
    ) -> Result<(), String> {
        self.deadline = deadline;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::thread;

    #[test]
    fn inproc_roundtrip() {
        let (mut a, mut b) = InProcTransport::pair();
        a.send(&Message::Stats).unwrap();
        assert_eq!(b.recv().unwrap(), Message::Stats);
        b.send(&Message::PushAck { clock: 5 }).unwrap();
        assert_eq!(a.recv().unwrap(), Message::PushAck { clock: 5 });
    }

    #[test]
    fn inproc_disconnect_detected() {
        let (mut a, b) = InProcTransport::pair();
        drop(b);
        assert!(a.send(&Message::Stats).is_err());
    }

    #[test]
    fn tcp_roundtrip_with_tensors() {
        let listener = listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap(); // echo
        });
        let mut c = connect(addr).unwrap();
        let msg = Message::Push {
            worker: 9,
            step: 3,
            seq: 1,
            epoch: u64::MAX,
            entries: vec![(0, Tensor::from_vec(&[128], vec![0.25; 128]))],
        };
        c.send(&msg).unwrap();
        assert_eq!(c.recv().unwrap(), msg);
        server.join().unwrap();
    }

    #[test]
    fn send_with_framing_matches_send() {
        use crate::net::message::wire;

        // In-proc: a streamed frame decodes identically to an owned send.
        let (mut a, mut b) = InProcTransport::pair();
        let t = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        a.send_with(&mut |w| {
            wire::push_header(w, 3, 11, 4, u64::MAX, 1);
            wire::entry(w, 0, &t);
        })
        .unwrap();
        assert_eq!(
            b.recv().unwrap(),
            Message::Push { worker: 3, step: 11, seq: 4, epoch: u64::MAX, entries: vec![(0, t.clone())] }
        );

        // TCP: same, over a real socket, twice (buffer reuse).
        let listener = listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut s = TcpTransport::new(stream).unwrap();
            let m1 = s.recv().unwrap();
            let m2 = s.recv().unwrap();
            (m1, m2)
        });
        let mut c = connect(addr).unwrap();
        c.send_with(&mut |w| {
            wire::pull_reply_header(w, 5, 1);
            wire::entry(w, 2, &t);
        })
        .unwrap();
        c.send_with(&mut |w| Message::Stats.encode_into(w)).unwrap();
        let (m1, m2) = server.join().unwrap();
        assert_eq!(m1, Message::PullReply { clock: 5, entries: vec![(2, t)] });
        assert_eq!(m2, Message::Stats);
    }

    #[test]
    fn recv_with_borrows_raw_frame() {
        // In-proc: the closure sees exactly the encoded body bytes.
        let (mut a, mut b) = InProcTransport::pair();
        let msg = Message::PushAck { clock: 12 };
        a.send(&msg).unwrap();
        let mut seen = Vec::new();
        b.recv_with(&mut |frame| {
            seen = frame.to_vec();
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, msg.encode());

        // TCP: recv_with and recv interleave on one persistent buffer.
        let listener = listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let mut first = Vec::new();
            t.recv_with(&mut |frame| {
                first = frame.to_vec();
                Ok(())
            })
            .unwrap();
            let second = t.recv().unwrap();
            (first, second)
        });
        let mut c = connect(addr).unwrap();
        c.send(&Message::Barrier { worker: 1, step: 2, epoch: u64::MAX }).unwrap();
        c.send(&Message::Stats).unwrap();
        let (first, second) = server.join().unwrap();
        assert_eq!(first, Message::Barrier { worker: 1, step: 2, epoch: u64::MAX }.encode());
        assert_eq!(second, Message::Stats);

        // A decode error propagates out of recv_with.
        let (mut a, mut b) = InProcTransport::pair();
        a.send(&Message::Stats).unwrap();
        assert!(b
            .recv_with(&mut |_| Err("decode failed".to_string()))
            .is_err());
    }

    #[test]
    fn read_deadline_turns_silence_into_retryable_error() {
        use std::time::Duration;

        // In-proc: a silent peer surfaces as an error within the
        // deadline; clearing the deadline restores blocking reads.
        let (mut a, mut b) = InProcTransport::pair();
        a.set_read_deadline(Some(Duration::from_millis(20))).unwrap();
        assert!(a.recv().unwrap_err().contains("timed out"));
        b.send(&Message::Stats).unwrap();
        assert_eq!(a.recv().unwrap(), Message::Stats);
        a.set_read_deadline(None).unwrap();

        // TCP: same contract over a real socket — the server stays
        // silent, the deadlined client errors instead of hanging, and
        // the connection still works once traffic resumes.
        let listener = listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            assert_eq!(t.recv().unwrap(), Message::Ping);
            t.send(&Message::Pong { epoch: 0, is_primary: true }).unwrap();
        });
        let mut c = connect(addr).unwrap();
        c.set_read_deadline(Some(Duration::from_millis(20))).unwrap();
        assert!(c.recv().is_err(), "silent server must not block past the deadline");
        c.send(&Message::Ping).unwrap();
        c.set_read_deadline(Some(Duration::from_secs(10))).unwrap();
        assert_eq!(c.recv().unwrap(), Message::Pong { epoch: 0, is_primary: true });
        server.join().unwrap();
    }

    #[test]
    fn tcp_many_messages_in_order() {
        let listener = listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            for i in 0..100u64 {
                match t.recv().unwrap() {
                    Message::Barrier { step, .. } => assert_eq!(step, i),
                    m => panic!("unexpected {m:?}"),
                }
            }
            t.send(&Message::BarrierRelease { step: 99 }).unwrap();
        });
        let mut c = connect(addr).unwrap();
        for i in 0..100u64 {
            c.send(&Message::Barrier { worker: 0, step: i, epoch: u64::MAX }).unwrap();
        }
        assert_eq!(c.recv().unwrap(), Message::BarrierRelease { step: 99 });
        server.join().unwrap();
    }
}
