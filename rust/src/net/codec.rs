//! Binary wire codec (serde substitute).
//!
//! Little-endian, length-prefixed primitives. `Writer` appends into a
//! reusable byte buffer; `Reader` is a zero-copy cursor over a received
//! frame. Tensors are encoded as shape + raw f32 payload; on the hot
//! path the payload is appended with a single bulk copy.

use crate::tensor::Tensor;

/// Append-only encoder over an owned buffer.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer { buf: Vec::with_capacity(n) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Append raw bytes with no length prefix (payloads whose length the
    /// enclosing message already carries, e.g. compressed-push bodies).
    pub fn raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// u32 payload with no length prefix — one bulk copy on LE hosts,
    /// byte-identical to per-element [`u32`](Self::u32) calls.
    pub fn u32_raw(&mut self, v: &[u32]) {
        #[cfg(target_endian = "little")]
        {
            // SAFETY: any u32 bit pattern is valid to view as bytes, u8
            // has alignment 1, and `size_of_val(v) == 4 * v.len()`.
            let bytes = unsafe {
                std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v))
            };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        {
            for x in v {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    /// f32 payload with no length prefix — one bulk copy on LE hosts,
    /// byte-identical to per-element [`f32`](Self::f32) calls.
    pub fn f32_raw(&mut self, v: &[f32]) {
        #[cfg(target_endian = "little")]
        {
            // SAFETY: same as `f32_slice` — every f32 bit pattern is
            // valid bytes, alignment 1.
            let bytes = unsafe {
                std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v))
            };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        {
            for x in v {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// f32 slice with one bulk copy (hot path: gradients/parameters).
    ///
    /// On little-endian hosts the in-memory `[f32]` layout IS the wire
    /// format, so the payload is appended with a single `memcpy`; other
    /// hosts fall back to per-element encoding.
    pub fn f32_slice(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        #[cfg(target_endian = "little")]
        {
            // SAFETY: every bit pattern of f32 is valid to view as bytes,
            // u8 has alignment 1, and `size_of_val(v) == 4 * v.len()`.
            let bytes = unsafe {
                std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v))
            };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        {
            for x in v {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    pub fn tensor(&mut self, t: &Tensor) {
        self.u32(t.shape().len() as u32);
        for d in t.shape() {
            self.u32(*d as u32);
        }
        self.f32_slice(t.data());
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    /// Reset for reuse, keeping the allocation (hot-path frame buffers).
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Shrink the backing allocation to at most `min_capacity` (or the
    /// current length, if larger) — lets long-lived frame buffers drop
    /// the memory of a one-off oversized frame.
    pub fn shrink_to(&mut self, min_capacity: usize) {
        self.buf.shrink_to(min_capacity);
    }

    /// Roll back to an earlier length (abort a partially-encoded body
    /// and re-encode, e.g. replacing it with an error message).
    pub fn truncate(&mut self, len: usize) {
        self.buf.truncate(len);
    }

    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Borrow the encoded bytes without consuming the buffer.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Overwrite 4 bytes at `pos` (length-prefix patching after the body
    /// has been encoded in place). Panics if `pos + 4 > len`.
    pub fn set_u32(&mut self, pos: usize, v: u32) {
        self.buf[pos..pos + 4].copy_from_slice(&v.to_le_bytes());
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Cursor decoder over a borrowed frame.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "frame underrun: need {n} bytes at {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, String> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], String> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// Borrow the next `n` raw bytes (payloads whose length the caller
    /// already decoded — the streaming-decode twin of [`Writer::raw`]).
    pub fn raw(&mut self, n: usize) -> Result<&'a [u8], String> {
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String, String> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec()).map_err(|e| format!("invalid utf8: {e}"))
    }

    /// Decode a length-prefixed f32 payload. Little-endian hosts copy the
    /// raw bytes straight into the output vector in one `memcpy`; other
    /// hosts decode per element.
    pub fn f32_vec(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u32()? as usize;
        let b = self.take(n * 4)?;
        #[cfg(target_endian = "little")]
        {
            let mut out = vec![0.0f32; n];
            // SAFETY: `out` owns exactly n*4 bytes, viewing them as &mut
            // [u8] is valid (alignment 1), and on LE hosts the wire bytes
            // are the in-memory representation. Every bit pattern is a
            // valid f32.
            unsafe {
                std::slice::from_raw_parts_mut(out.as_mut_ptr().cast::<u8>(), n * 4)
                    .copy_from_slice(b);
            }
            Ok(out)
        }
        #[cfg(not(target_endian = "little"))]
        {
            Ok(b.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        }
    }

    pub fn tensor(&mut self) -> Result<Tensor, String> {
        let rank = self.u32()? as usize;
        if rank > 16 {
            return Err(format!("implausible tensor rank {rank}"));
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u32()? as usize);
        }
        let data = self.f32_vec()?;
        if shape.iter().product::<usize>() != data.len() {
            return Err(format!(
                "tensor shape {shape:?} disagrees with payload {}",
                data.len()
            ));
        }
        Ok(Tensor::from_vec(&shape, data))
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX);
        w.f32(-1.5);
        w.f64(std::f64::consts::PI);
        w.str("héllo");
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut w = Writer::new();
        w.tensor(&t);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.tensor().unwrap(), t);
    }

    #[test]
    fn underrun_detected() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.u32().is_err());
    }

    #[test]
    fn corrupt_tensor_shape_detected() {
        let mut w = Writer::new();
        w.u32(1); // rank 1
        w.u32(10); // shape [10]
        w.f32_slice(&[1.0, 2.0]); // only 2 elements
        let buf = w.finish();
        assert!(Reader::new(&buf).tensor().is_err());
    }

    #[test]
    fn f32_bulk_roundtrip_special_values() {
        // The bulk-copy fast path must preserve every bit pattern the
        // per-element path did, including negative zero and infinities.
        let vals = vec![0.0f32, -0.0, 1.5, -1.5, f32::INFINITY, f32::NEG_INFINITY, f32::MIN, f32::MAX, f32::EPSILON];
        let mut w = Writer::new();
        w.f32_slice(&vals);
        let buf = w.finish();
        // Wire layout: u32 count then per-element to_le_bytes.
        assert_eq!(buf.len(), 4 + vals.len() * 4);
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(&buf[4 + i * 4..8 + i * 4], &v.to_le_bytes());
        }
        let got = Reader::new(&buf).f32_vec().unwrap();
        assert_eq!(got.len(), vals.len());
        for (a, b) in got.iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn f32_bulk_roundtrip_empty() {
        let mut w = Writer::new();
        w.f32_slice(&[]);
        let buf = w.finish();
        assert_eq!(Reader::new(&buf).f32_vec().unwrap(), Vec::<f32>::new());
    }

    #[test]
    fn writer_reuse_and_patching() {
        let mut w = Writer::new();
        w.u32(0); // placeholder
        w.str("body");
        w.set_u32(0, (w.len() - 4) as u32);
        assert_eq!(w.as_bytes()[0..4], ((w.len() - 4) as u32).to_le_bytes());
        w.clear();
        assert!(w.is_empty());
        w.u8(9);
        assert_eq!(w.as_bytes(), &[9]);
    }

    #[test]
    fn raw_bulk_helpers_match_per_element_encoding() {
        let us = [0u32, 1, 0xDEAD_BEEF, u32::MAX];
        let fs = [0.0f32, -0.0, 1.5, f32::INFINITY, f32::MIN];
        let mut bulk = Writer::new();
        bulk.u32_raw(&us);
        bulk.f32_raw(&fs);
        let mut scalar = Writer::new();
        for &u in &us {
            scalar.u32(u);
        }
        for &f in &fs {
            scalar.f32(f);
        }
        assert_eq!(bulk.finish(), scalar.finish());
    }

    #[test]
    fn raw_roundtrip_unprefixed() {
        let mut w = Writer::new();
        w.u32(3); // caller-owned length
        w.raw(&[7, 8, 9]);
        w.str("after");
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        let n = r.u32().unwrap() as usize;
        assert_eq!(r.raw(n).unwrap(), &[7, 8, 9]);
        assert_eq!(r.str().unwrap(), "after");
        assert!(r.raw(1).is_err()); // underrun detected
    }

    #[test]
    fn prop_roundtrip_random_tensors() {
        prop::run(50, 0xC0DEC, |g| {
            let rank = g.usize(0, 3);
            let shape: Vec<usize> = (0..rank).map(|_| g.usize(1, 8)).collect();
            let n: usize = shape.iter().product();
            let data = g.vec_f32(n, -1e6, 1e6);
            let t = Tensor::from_vec(&shape, data);
            let mut w = Writer::new();
            w.tensor(&t);
            w.str("trailer");
            let buf = w.finish();
            let mut r = Reader::new(&buf);
            assert_eq!(r.tensor().unwrap(), t);
            assert_eq!(r.str().unwrap(), "trailer");
        });
    }
}
