//! Networking substrate: binary codec, protocol messages, framed
//! transports (TCP and in-process) and deterministic fault injection
//! for the parameter-server protocol.

pub mod codec;
pub mod fault;
pub mod message;
pub mod transport;

pub use codec::{Reader, Writer};
pub use fault::{FaultEvent, FaultKind, FaultLog, FaultPlan, FaultyTransport};
pub use message::Message;
pub use transport::{connect, listen, InProcTransport, TcpTransport, Transport};
