//! Networking substrate: binary codec, protocol messages, and framed
//! transports (TCP and in-process) for the parameter-server protocol.

pub mod codec;
pub mod message;
pub mod transport;

pub use codec::{Reader, Writer};
pub use message::Message;
pub use transport::{connect, listen, InProcTransport, TcpTransport, Transport};
