//! Networking substrate: binary codec, protocol messages, framed
//! transports (TCP and in-process), deterministic fault injection for
//! the parameter-server protocol, and peer-to-peer collectives (ring +
//! tree allreduce) for the PS-free backend.

pub mod codec;
pub mod collective;
pub mod fault;
pub mod message;
pub mod transport;

pub use codec::{Reader, Writer};
pub use collective::{Collective, Contrib, Topology};
pub use fault::{FaultEvent, FaultKind, FaultLog, FaultPlan, FaultyTransport};
pub use message::Message;
pub use transport::{connect, listen, InProcTransport, TcpTransport, Transport};
