//! Peer-to-peer collective aggregation: ring + tree allreduce.
//!
//! The second data-parallel backend (`train-dist --backend allreduce`)
//! replaces the parameter-server fleet with a worker-only collective:
//! every rank holds a full model replica, and each step the ranks
//! allreduce their gradient contributions and apply the identical mean
//! locally. FireCaffe (arXiv:1511.00175) showed reduction trees beating
//! parameter servers at scale; Shi et al. (arXiv:1711.05979) model the
//! PS-vs-allreduce trade-off this module realizes — see
//! `advisor::lemmas::choose_backend` for the cost model that picks a
//! side.
//!
//! # Topologies
//!
//! * **Ring, dense** — the classic chunked ring allreduce:
//!   reduce-scatter (N−1 rounds, each rank accumulates one segment)
//!   then allgather (N−1 rounds, the finished segments circulate).
//!   Per-rank traffic is `2 (N−1)/N · S` regardless of N — bandwidth
//!   optimal. Segment sums accumulate in ring order, so the result is a
//!   *sum* with ring-rotation association (identical bytes on every
//!   rank, since each segment is finished exactly once and then
//!   copied).
//! * **Ring, compressed** — codecs are per-key, stateful (top-k error
//!   feedback) and non-linear, so compressed bodies cannot be summed
//!   mid-ring. Instead each rank compresses its own gradient once and
//!   the *contributions* relay around the ring verbatim (N−1 hops);
//!   every rank then folds all N contributions **flat, in rank order**
//!   — the same left-associated accumulation the PS sync fold uses, so
//!   identical inputs produce bit-identical sums.
//! * **Tree** — contributions stream up a binary tree to the root
//!   (rank 0), which folds them flat in rank order — again exactly the
//!   PS fold — and broadcasts the dense sum back down. Every rank
//!   applies the root's bytes, so the replicas stay bit-identical.
//!   Latency is `O(log N)` rounds; the root pays `O(N·S)` inbound.
//! * **Halving-doubling (`hd`), dense** — recursive halving
//!   reduce-scatter (`log₂ N` rounds, partner `rank ^ s`, each round
//!   exchanges half the live range) followed by recursive doubling
//!   allgather (Shi et al. arXiv:1711.05979): bandwidth-optimal like
//!   the ring (`2 (N−1)/N · S` per rank) but only `2 log₂ N` latency
//!   terms instead of `2 (N−1)`. Non-power-of-two groups pre-combine
//!   the extra ranks into their `rank − p` partner and broadcast the
//!   result back after the core exchange. Each segment is finished by
//!   exactly one rank and then copied, so replicas stay bit-identical.
//! * **Halving-doubling, compressed** — compressed bodies cannot be
//!   summed mid-exchange (stateful, non-linear codecs), so `hd` falls
//!   back to the ring's contribution relay and the flat rank-order
//!   fold — identical bytes to the compressed ring.
//!
//! # Fault behavior
//!
//! Collectives hang when a peer wedges — unless every receive is
//! bounded. All links carry a read deadline: a per-chunk base (default
//! [`DEFAULT_DEADLINE_MS`], settable via
//! [`Collective::set_deadline`]) scaled by how many chunks — and, for
//! the overlapped committer, how many concurrent buckets — may
//! legitimately be queued ahead of any single receive, clamped to
//! [`DEFAULT_DEADLINE_CAP_MS`] (a fixed deadline fires spuriously on
//! large overlapped transfers; a scaled one stays proportional to the
//! outstanding work while the cap keeps every wait bounded). A
//! dropped, severed or wedged peer turns into a clean `Err` from the
//! collective call, which the coordinator's reform loop
//! (`coordinator::distributed::run_allreduce`) handles by rebuilding
//! the group from the surviving ranks' committed state. A collective
//! op never blocks forever — chaos-tested with
//! `net::fault::FaultyTransport` in `tests/chaos.rs`.
//!
//! # Wire format
//!
//! Collective links are private rank-to-rank connections; their frames
//! use tags ≥ 40, disjoint from `net::message` (which owns 1..=29), and
//! never pass through `Message::decode`:
//!
//! | frame | payload |
//! |-------|---------|
//! | chunk (40) | `u64 step, u8 phase, u32 seg, u32 chunk, u32 n, n × f32` |
//! | contribution (41) | `u64 step, u32 owner, u32 n, n × (u32 key, u8 kind, body)` |
//! | dense sum (42) | `u64 step, u32 n, n × (u32 numel, numel × f32)` |
//!
//! Contribution bodies: kind 0 = dense (`u32 numel, numel × f32`),
//! kind 1 = sparse top-k (`u32 numel, u32 k, k × u32 idx, k × f32
//! val`), kind 2 = quant8 (`u32 numel, u32 qlen, f32 scale, qlen ×
//! i8`) — the compressed bodies byte-match the `CompressedPush` entry
//! bodies, so the advisor's traffic accounting transfers unchanged.

use std::time::Duration;

use crate::net::codec::{Reader, Writer};
use crate::net::transport::{InProcTransport, Transport};
use crate::ps::compress::Compressed;
use crate::tensor::Tensor;

/// Frame tags for collective links (disjoint from `net::message`).
const F_CHUNK: u8 = 40;
const F_CONTRIB: u8 = 41;
const F_SUM: u8 = 42;

/// Contribution-entry kind bytes.
const K_DENSE: u8 = 0;
const K_SPARSE: u8 = 1;
const K_QUANT8: u8 = 2;

/// Ring phase bytes (desync detection).
const P_REDUCE: u8 = 0;
const P_GATHER: u8 = 1;

/// Default floats per ring chunk (64 KiB frames): big enough to
/// amortize framing, small enough to pipeline send/recv and never
/// deadlock head-to-head TCP sends.
pub const DEFAULT_CHUNK_FLOATS: usize = 16_384;

/// Default per-chunk receive-deadline base on collective links. A
/// wedged peer surfaces as an `Err` within the scaled bound instead of
/// hanging the collective.
pub const DEFAULT_DEADLINE_MS: u64 = 5_000;

/// Hard ceiling on any single effective receive deadline, however many
/// chunks or overlapped buckets are in flight. Liveness stays bounded
/// even for huge transfers.
pub const DEFAULT_DEADLINE_CAP_MS: u64 = 60_000;

/// Sentinel segment index for the halving-doubling pre-combine /
/// post-broadcast exchanges with extra (non-power-of-two) ranks.
const HD_PRE_SEG: usize = u32::MAX as usize;

/// Collective topology. `Ring` is bandwidth-optimal; `Tree` is
/// latency-optimal; `Hd` (recursive halving-doubling) matches the
/// ring's bandwidth with only `2 log₂ N` latency terms —
/// `advisor::lemmas::choose_backend` prices all three from the Lemma
/// 3.2 inputs (`hd` is opt-in via `--topology hd`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    Ring,
    Tree,
    Hd,
}

impl Topology {
    /// Parse a `--topology` flag value (`ring`, `tree` or `hd`).
    pub fn parse(s: &str) -> Result<Topology, String> {
        match s {
            "ring" => Ok(Topology::Ring),
            "tree" => Ok(Topology::Tree),
            "hd" => Ok(Topology::Hd),
            other => Err(format!("unknown topology {other:?} (ring|tree|hd)")),
        }
    }

    /// The flag spelling this topology parses from (for reports).
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Ring => "ring",
            Topology::Tree => "tree",
            Topology::Hd => "hd",
        }
    }
}

/// One rank's per-key gradient contribution: dense, or compressed by
/// the push codec (the exact same [`Compressed`] the PS client would
/// have put on the wire).
#[derive(Debug, Clone, PartialEq)]
pub enum Contrib {
    Dense(Tensor),
    Comp(Compressed),
}

/// One rank's links to its peers, indexed by peer rank (`None` at the
/// rank's own slot).
pub type Links = Vec<Option<Box<dyn Transport>>>;

/// Build a full in-process mesh: `mesh(n)[i][j]` is rank `i`'s link to
/// rank `j`. The run path wraps these in `FaultyTransport` for chaos
/// runs; ring/tree only use the neighbor/parent-child subset.
pub fn inproc_mesh(n: usize) -> Vec<Links> {
    let mut rows: Vec<Links> = (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
    for i in 0..n {
        for j in (i + 1)..n {
            let (a, b) = InProcTransport::pair();
            rows[i][j] = Some(Box::new(a) as Box<dyn Transport>);
            rows[j][i] = Some(Box::new(b) as Box<dyn Transport>);
        }
    }
    rows
}

fn subtree_size(n: usize, i: usize) -> usize {
    if i >= n {
        0
    } else {
        1 + subtree_size(n, 2 * i + 1) + subtree_size(n, 2 * i + 2)
    }
}

/// One rank's handle on the collective group: its links, the model's
/// key shapes (every rank holds the full model), and wire-byte
/// counters split by direction — `reduce` (reduce-scatter / relay /
/// gather-up, the push-direction analogue) and `bcast` (allgather /
/// broadcast-down, the pull-direction analogue).
pub struct Collective {
    rank: usize,
    n: usize,
    links: Links,
    topology: Topology,
    shapes: Vec<Vec<usize>>,
    chunk_floats: usize,
    /// Per-chunk read-deadline base; the effective per-receive deadline
    /// is scaled by the transfer's chunk count (see [`scaled_deadline`])
    /// at every allreduce entry.
    deadline_base: Duration,
    /// Ceiling on any effective receive deadline.
    deadline_cap: Duration,
    /// Concurrent-bucket hint from the overlapped committer: with k
    /// buckets queued behind one link, any single receive may
    /// legitimately wait k times longer.
    inflight_buckets: usize,
    reduce_bytes: u64,
    bcast_bytes: u64,
}

impl Collective {
    /// Join the group as `rank` of `n`: validates the link table
    /// (exactly `n` slots, no self-link) and arms every link's read
    /// deadline. `shapes` registers the full model's key shapes —
    /// identical on every rank, since any rank may finish any segment.
    pub fn new(
        rank: usize,
        n: usize,
        mut links: Links,
        topology: Topology,
        shapes: Vec<Vec<usize>>,
    ) -> Result<Collective, String> {
        if n == 0 || rank >= n {
            return Err(format!("bad collective rank {rank} of {n}"));
        }
        if links.len() != n {
            return Err(format!("rank {rank}: {} links for {n} ranks", links.len()));
        }
        if links[rank].is_some() {
            return Err(format!("rank {rank}: self-link present"));
        }
        let d = Duration::from_millis(DEFAULT_DEADLINE_MS);
        for l in links.iter_mut().flatten() {
            l.set_read_deadline(Some(d))?;
        }
        Ok(Collective {
            rank,
            n,
            links,
            topology,
            shapes,
            chunk_floats: DEFAULT_CHUNK_FLOATS,
            deadline_base: d,
            deadline_cap: Duration::from_millis(DEFAULT_DEADLINE_CAP_MS),
            inflight_buckets: 1,
            reduce_bytes: 0,
            bcast_bytes: 0,
        })
    }

    /// Bound every receive on this rank's links. The collective's
    /// liveness guarantee — a wedged peer is an `Err`, never a hang —
    /// is this per-chunk base, scaled per transfer by the in-flight
    /// chunk/bucket count and clamped to the cap.
    pub fn set_deadline(&mut self, d: Duration) -> Result<(), String> {
        self.deadline_base = d;
        for l in self.links.iter_mut().flatten() {
            l.set_read_deadline(Some(d))?;
        }
        Ok(())
    }

    /// Tell the deadline scaler how many buckets the overlapped
    /// committer may queue concurrently (1 = serial commits).
    pub fn set_inflight_buckets(&mut self, buckets: usize) {
        self.inflight_buckets = buckets.max(1);
    }

    /// This rank's index within the group.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Group size N.
    pub fn n_ranks(&self) -> usize {
        self.n
    }

    /// Bytes this rank sent in the reduce direction (reduce-scatter,
    /// contribution relay, gather-up).
    pub fn reduce_wire_bytes(&self) -> u64 {
        self.reduce_bytes
    }

    /// Bytes this rank sent in the broadcast direction (allgather,
    /// broadcast-down).
    pub fn bcast_wire_bytes(&self) -> u64 {
        self.bcast_bytes
    }

    fn link(&mut self, peer: usize) -> Result<&mut Box<dyn Transport>, String> {
        self.links
            .get_mut(peer)
            .and_then(|l| l.as_mut())
            .ok_or_else(|| format!("no link to rank {peer}"))
    }

    /// Allreduce this step's contributions into the per-key **sum**
    /// over all ranks (callers scale by `1/N` — the same
    /// scale-then-apply the PS sync release performs). Every rank
    /// returns bit-identical tensors. Errors are clean and bounded:
    /// a dead or wedged peer fails the call within the read deadline.
    pub fn allreduce_sum(
        &mut self,
        step: u64,
        mine: Vec<Contrib>,
    ) -> Result<Vec<Tensor>, String> {
        let keys: Vec<usize> = (0..self.shapes.len()).collect();
        self.allreduce_sum_keys(step, &keys, mine)
    }

    /// Allreduce a **subset** of keys under a caller-chosen `tag` — the
    /// bucketized entry point for the overlapped committer, which runs
    /// one collective per bucket with `tag = (step << 16) | bucket`.
    /// `keys` must be ascending, in-range indices into the registered
    /// shape list, and `mine[i]` the contribution for `keys[i]`. Every
    /// rank must call with the same `(tag, keys)` sequence; the tag
    /// rides the wire frames exactly where the step used to, so any
    /// desync between ranks is a clean decode error.
    pub fn allreduce_sum_keys(
        &mut self,
        tag: u64,
        keys: &[usize],
        mine: Vec<Contrib>,
    ) -> Result<Vec<Tensor>, String> {
        if mine.len() != keys.len() {
            return Err(format!(
                "rank {}: {} contributions for {} keys",
                self.rank,
                mine.len(),
                keys.len()
            ));
        }
        if keys.windows(2).any(|w| w[0] >= w[1]) || keys.iter().any(|&k| k >= self.shapes.len()) {
            return Err(format!("rank {}: bad key set {keys:?}", self.rank));
        }
        let sub: Vec<Vec<usize>> = keys.iter().map(|&k| self.shapes[k].clone()).collect();
        if self.n == 1 {
            return fold_rank_order(&sub, &[mine]);
        }
        // Scale every link's receive deadline to what this transfer can
        // legitimately queue ahead of a single receive: its own chunk
        // count times however many buckets the committer keeps in
        // flight. A fixed per-receive deadline fires spuriously on
        // large overlapped transfers.
        let total: usize = sub.iter().map(|s| s.iter().product::<usize>()).sum();
        let d = scaled_deadline(
            self.deadline_base,
            self.deadline_cap,
            self.chunk_floats,
            total,
            self.inflight_buckets,
        );
        for l in self.links.iter_mut().flatten() {
            l.set_read_deadline(Some(d))?;
        }
        let all_dense = mine.iter().all(|c| matches!(c, Contrib::Dense(_)));
        match self.topology {
            Topology::Ring if all_dense => self.ring_dense(tag, &sub, mine),
            Topology::Ring => self.ring_relay(tag, &sub, mine),
            Topology::Hd if all_dense => self.hd_dense(tag, &sub, mine),
            // Compressed bodies can't be summed mid-exchange, so hd
            // falls back to the flat rank-order contribution relay —
            // identical bytes to the compressed ring.
            Topology::Hd => self.ring_relay(tag, &sub, mine),
            Topology::Tree => self.tree_sum(tag, &sub, mine),
        }
    }

    // ---- dense ring: chunked reduce-scatter + allgather ------------

    fn ring_dense(
        &mut self,
        tag: u64,
        shapes: &[Vec<usize>],
        mine: Vec<Contrib>,
    ) -> Result<Vec<Tensor>, String> {
        let mut buf = Vec::new();
        for (k, c) in mine.iter().enumerate() {
            let Contrib::Dense(t) = c else { unreachable!() };
            if t.shape() != &shapes[k][..] {
                return Err(format!("rank {}: key {k} shape mismatch", self.rank));
            }
            buf.extend_from_slice(t.data());
        }
        let n = self.n;
        // Reduce-scatter: after round r this rank has accumulated r+2
        // contributions into segment (rank - r - 1) mod n; after n-1
        // rounds it owns the finished segment (rank + 1) mod n.
        for r in 0..n - 1 {
            let send_seg = (self.rank + n - r) % n;
            let recv_seg = (self.rank + n - r - 1) % n;
            self.exchange_seg(tag, P_REDUCE, send_seg, recv_seg, &mut buf, true)?;
        }
        // Allgather: finished segments circulate; receives overwrite.
        for r in 0..n - 1 {
            let send_seg = (self.rank + 1 + n - r) % n;
            let recv_seg = (self.rank + n - r) % n;
            self.exchange_seg(tag, P_GATHER, send_seg, recv_seg, &mut buf, false)?;
        }
        Ok(unflatten(shapes, &buf))
    }

    fn seg_bounds(&self, len: usize, seg: usize) -> (usize, usize) {
        (seg * len / self.n, (seg + 1) * len / self.n)
    }

    /// One ring round: send `send_seg` to the right neighbor while
    /// receiving `recv_seg` from the left, chunk-interleaved so neither
    /// side ever has more than one chunk outstanding past the socket
    /// buffer (no head-to-head send deadlock over TCP).
    fn exchange_seg(
        &mut self,
        step: u64,
        phase: u8,
        send_seg: usize,
        recv_seg: usize,
        buf: &mut [f32],
        accumulate: bool,
    ) -> Result<(), String> {
        let right = (self.rank + 1) % self.n;
        let left = (self.rank + self.n - 1) % self.n;
        let (ss, se) = self.seg_bounds(buf.len(), send_seg);
        let (rs, re) = self.seg_bounds(buf.len(), recv_seg);
        let chunk = self.chunk_floats.max(1);
        let n_send = (se - ss).div_ceil(chunk);
        let n_recv = (re - rs).div_ceil(chunk);
        for k in 0..n_send.max(n_recv) {
            if k < n_send {
                let a = ss + k * chunk;
                let b = (a + chunk).min(se);
                let slice = &buf[a..b];
                let (seg32, k32, n32) = (send_seg as u32, k as u32, slice.len() as u32);
                self.link(right)?.send_with(&mut |w: &mut Writer| {
                    w.u8(F_CHUNK);
                    w.u64(step);
                    w.u8(phase);
                    w.u32(seg32);
                    w.u32(k32);
                    w.u32(n32);
                    w.f32_raw(slice);
                })?;
                let sent = 22 + 4 * (b - a) as u64;
                if phase == P_REDUCE {
                    self.reduce_bytes += sent;
                } else {
                    self.bcast_bytes += sent;
                }
            }
            if k < n_recv {
                let a = rs + k * chunk;
                let b = (a + chunk).min(re);
                let dst = &mut buf[a..b];
                let mut res: Result<(), String> = Ok(());
                self.links[left]
                    .as_mut()
                    .ok_or_else(|| format!("no link to rank {left}"))?
                    .recv_with(&mut |body: &[u8]| {
                        res = read_chunk_into(body, step, phase, recv_seg, k, dst, accumulate);
                        Ok(())
                    })?;
                res?;
            }
        }
        Ok(())
    }

    // ---- compressed ring: contribution relay -----------------------

    fn ring_relay(
        &mut self,
        tag: u64,
        shapes: &[Vec<usize>],
        mine: Vec<Contrib>,
    ) -> Result<Vec<Tensor>, String> {
        let n = self.n;
        let right = (self.rank + 1) % n;
        let left = (self.rank + n - 1) % n;
        // Send own contribution once; it relays all the way around.
        let own = encode_contrib(tag, self.rank as u32, &mine);
        self.link(right)?.send_with(&mut |w: &mut Writer| w.raw(&own))?;
        self.reduce_bytes += own.len() as u64;
        let mut per_rank: Vec<Option<Vec<Contrib>>> = (0..n).map(|_| None).collect();
        per_rank[self.rank] = Some(mine);
        for r in 0..n - 1 {
            let expect_owner = (self.rank + n - 1 - r) % n;
            let mut frame = Vec::new();
            self.links[left]
                .as_mut()
                .ok_or_else(|| format!("no link to rank {left}"))?
                .recv_with(&mut |body: &[u8]| {
                    frame.extend_from_slice(body);
                    Ok(())
                })?;
            let (owner, entries) = decode_contrib(&frame, tag, shapes)?;
            if owner as usize != expect_owner {
                return Err(format!(
                    "collective desync: contribution from rank {owner}, expected {expect_owner}"
                ));
            }
            // Relay unless the right neighbor is the owner (frame has
            // then completed its loop).
            if right != owner as usize {
                self.link(right)?.send_with(&mut |w: &mut Writer| w.raw(&frame))?;
                self.reduce_bytes += frame.len() as u64;
            }
            per_rank[owner as usize] = Some(entries);
        }
        let ordered: Vec<Vec<Contrib>> = per_rank
            .into_iter()
            .map(|c| c.ok_or_else(|| "collective desync: missing contribution".to_string()))
            .collect::<Result<_, _>>()?;
        fold_rank_order(shapes, &ordered)
    }

    // ---- tree: gather contributions to root, broadcast dense sum ---

    fn tree_sum(
        &mut self,
        tag: u64,
        shapes: &[Vec<usize>],
        mine: Vec<Contrib>,
    ) -> Result<Vec<Tensor>, String> {
        let n = self.n;
        let parent = if self.rank == 0 { None } else { Some((self.rank - 1) / 2) };
        let children: Vec<usize> =
            [2 * self.rank + 1, 2 * self.rank + 2].into_iter().filter(|&c| c < n).collect();
        // Gather up: own contribution first, then relay each child's
        // subtree verbatim. The root decodes everything.
        let mut per_rank: Vec<Option<Vec<Contrib>>> = (0..n).map(|_| None).collect();
        if let Some(p) = parent {
            let own = encode_contrib(tag, self.rank as u32, &mine);
            self.link(p)?.send_with(&mut |w: &mut Writer| w.raw(&own))?;
            self.reduce_bytes += own.len() as u64;
        }
        per_rank[self.rank] = Some(mine);
        for &c in &children {
            for _ in 0..subtree_size(n, c) {
                let mut frame = Vec::new();
                self.links[c]
                    .as_mut()
                    .ok_or_else(|| format!("no link to rank {c}"))?
                    .recv_with(&mut |body: &[u8]| {
                        frame.extend_from_slice(body);
                        Ok(())
                    })?;
                if let Some(p) = parent {
                    self.link(p)?.send_with(&mut |w: &mut Writer| w.raw(&frame))?;
                    self.reduce_bytes += frame.len() as u64;
                } else {
                    let (owner, entries) = decode_contrib(&frame, tag, shapes)?;
                    if (owner as usize) >= n || per_rank[owner as usize].is_some() {
                        return Err(format!(
                            "collective desync: duplicate contribution from rank {owner}"
                        ));
                    }
                    per_rank[owner as usize] = Some(entries);
                }
            }
        }
        // Root folds flat in rank order — the exact PS sync fold — and
        // broadcasts the dense sum; everyone applies the same bytes.
        let sums = if parent.is_none() {
            let ordered: Vec<Vec<Contrib>> = per_rank
                .into_iter()
                .map(|c| c.ok_or_else(|| "collective desync: missing contribution".to_string()))
                .collect::<Result<_, _>>()?;
            fold_rank_order(shapes, &ordered)?
        } else {
            let p = parent.unwrap();
            let mut frame = Vec::new();
            self.links[p]
                .as_mut()
                .ok_or_else(|| format!("no link to rank {p}"))?
                .recv_with(&mut |body: &[u8]| {
                    frame.extend_from_slice(body);
                    Ok(())
                })?;
            decode_sum(&frame, tag, shapes)?
        };
        if !children.is_empty() {
            let frame = encode_sum(tag, &sums);
            for &c in &children {
                self.link(c)?.send_with(&mut |w: &mut Writer| w.raw(&frame))?;
                self.bcast_bytes += frame.len() as u64;
            }
        }
        Ok(sums)
    }

    // ---- dense halving-doubling: recursive reduce-scatter + allgather

    fn hd_dense(
        &mut self,
        tag: u64,
        shapes: &[Vec<usize>],
        mine: Vec<Contrib>,
    ) -> Result<Vec<Tensor>, String> {
        let mut buf = Vec::new();
        for (k, c) in mine.iter().enumerate() {
            let Contrib::Dense(t) = c else { unreachable!() };
            if t.shape() != &shapes[k][..] {
                return Err(format!("rank {}: key {k} shape mismatch", self.rank));
            }
            buf.extend_from_slice(t.data());
        }
        let n = self.n;
        let p = pow2_floor(n);
        let len = buf.len();
        if self.rank >= p {
            // Extra rank: fold the whole contribution into rank - p,
            // then receive the finished result back. No core exchange.
            let peer = self.rank - p;
            self.exchange_range(
                tag,
                peer,
                RangeXfer {
                    phase: P_REDUCE,
                    seg: HD_PRE_SEG,
                    send: (0, len),
                    recv: (0, 0),
                    accumulate: false,
                },
                &mut buf,
            )?;
            self.exchange_range(
                tag,
                peer,
                RangeXfer {
                    phase: P_GATHER,
                    seg: HD_PRE_SEG,
                    send: (0, 0),
                    recv: (0, len),
                    accumulate: false,
                },
                &mut buf,
            )?;
            return Ok(unflatten(shapes, &buf));
        }
        if self.rank + p < n {
            // Pre-combine the paired extra rank's full contribution so
            // the core exchange sums all n ranks.
            let peer = self.rank + p;
            self.exchange_range(
                tag,
                peer,
                RangeXfer {
                    phase: P_REDUCE,
                    seg: HD_PRE_SEG,
                    send: (0, 0),
                    recv: (0, len),
                    accumulate: true,
                },
                &mut buf,
            )?;
        }
        // Recursive halving reduce-scatter: each round swaps halves
        // with partner `rank ^ s` and accumulates the kept half; after
        // log2(p) rounds this rank owns one finished 1/p span.
        let mut s = p / 2;
        let mut round = 0usize;
        while s >= 1 {
            let partner = self.rank ^ s;
            let send = hd_span(len, p, partner, s);
            let recv = hd_span(len, p, self.rank, s);
            self.exchange_range(
                tag,
                partner,
                RangeXfer { phase: P_REDUCE, seg: round, send, recv, accumulate: true },
                &mut buf,
            )?;
            s /= 2;
            round += 1;
        }
        // Recursive doubling allgather: finished spans double each
        // round; receives overwrite, so every replica copies the exact
        // bytes the owning rank finished.
        let mut s = 1;
        let mut round = 0usize;
        while s < p {
            let partner = self.rank ^ s;
            let send = hd_span(len, p, self.rank, s);
            let recv = hd_span(len, p, partner, s);
            self.exchange_range(
                tag,
                partner,
                RangeXfer { phase: P_GATHER, seg: round, send, recv, accumulate: false },
                &mut buf,
            )?;
            s *= 2;
            round += 1;
        }
        if self.rank + p < n {
            // Broadcast the finished result back to the extra rank.
            let peer = self.rank + p;
            self.exchange_range(
                tag,
                peer,
                RangeXfer {
                    phase: P_GATHER,
                    seg: HD_PRE_SEG,
                    send: (0, len),
                    recv: (0, 0),
                    accumulate: false,
                },
                &mut buf,
            )?;
        }
        Ok(unflatten(shapes, &buf))
    }

    /// One pairwise halving-doubling round: stream `x.send` to `peer`
    /// while receiving `x.recv` from the same peer, chunk-interleaved
    /// exactly like [`Collective::exchange_seg`] so neither side ever
    /// has more than one chunk outstanding past the socket buffer. An
    /// empty range on either side is simply zero chunks.
    fn exchange_range(
        &mut self,
        tag: u64,
        peer: usize,
        x: RangeXfer,
        buf: &mut [f32],
    ) -> Result<(), String> {
        let (ss, se) = x.send;
        let (rs, re) = x.recv;
        let chunk = self.chunk_floats.max(1);
        let n_send = (se - ss).div_ceil(chunk);
        let n_recv = (re - rs).div_ceil(chunk);
        for k in 0..n_send.max(n_recv) {
            if k < n_send {
                let a = ss + k * chunk;
                let b = (a + chunk).min(se);
                let slice = &buf[a..b];
                let (seg32, k32, n32) = (x.seg as u32, k as u32, slice.len() as u32);
                self.link(peer)?.send_with(&mut |w: &mut Writer| {
                    w.u8(F_CHUNK);
                    w.u64(tag);
                    w.u8(x.phase);
                    w.u32(seg32);
                    w.u32(k32);
                    w.u32(n32);
                    w.f32_raw(slice);
                })?;
                let sent = 22 + 4 * (b - a) as u64;
                if x.phase == P_REDUCE {
                    self.reduce_bytes += sent;
                } else {
                    self.bcast_bytes += sent;
                }
            }
            if k < n_recv {
                let a = rs + k * chunk;
                let b = (a + chunk).min(re);
                let dst = &mut buf[a..b];
                let mut res: Result<(), String> = Ok(());
                self.links[peer]
                    .as_mut()
                    .ok_or_else(|| format!("no link to rank {peer}"))?
                    .recv_with(&mut |body: &[u8]| {
                        res = read_chunk_into(body, tag, x.phase, x.seg, k, dst, x.accumulate);
                        Ok(())
                    })?;
                res?;
            }
        }
        Ok(())
    }
}

/// One halving-doubling pairwise transfer: which range of the flat
/// buffer goes out, which comes in, and how the incoming floats land.
struct RangeXfer {
    phase: u8,
    seg: usize,
    send: (usize, usize),
    recv: (usize, usize),
    accumulate: bool,
}

/// Effective per-receive deadline for one transfer: the per-chunk base
/// times how many chunks (across all concurrently in-flight buckets)
/// may legitimately be queued ahead of any single receive, clamped to
/// `cap` so a misconfigured bucket count still fails in bounded time.
fn scaled_deadline(
    base: Duration,
    cap: Duration,
    chunk_floats: usize,
    total_floats: usize,
    inflight_buckets: usize,
) -> Duration {
    let chunks = total_floats.div_ceil(chunk_floats.max(1)).max(1) as u64;
    let scale = chunks.saturating_mul(inflight_buckets.max(1) as u64);
    let scale32 = u32::try_from(scale).unwrap_or(u32::MAX);
    cap.min(base.saturating_mul(scale32))
}

/// Largest power of two ≤ `n` (the halving-doubling core group size).
fn pow2_floor(n: usize) -> usize {
    let mut p = 1;
    while p * 2 <= n {
        p *= 2;
    }
    p
}

/// The sub-range of a `len`-float buffer that rank `r` (of a `p`-rank
/// power-of-two core) works on once the recursive bisection has
/// reached stride `s_min`: bisect from the top, keeping the half that
/// contains `r` at each stride. `s_min = 1` is rank `r`'s finished
/// 1/p span; larger strides are the partially-merged spans the
/// allgather sends back out.
fn hd_span(len: usize, p: usize, r: usize, s_min: usize) -> (usize, usize) {
    let (mut lo, mut hi) = (0, len);
    let mut s = p / 2;
    while s >= s_min.max(1) {
        let mid = lo + (hi - lo) / 2;
        if r & s == 0 {
            hi = mid;
        } else {
            lo = mid;
        }
        s /= 2;
    }
    (lo, hi)
}

/// Split a flat float buffer back into per-key tensors.
fn unflatten(shapes: &[Vec<usize>], buf: &[f32]) -> Vec<Tensor> {
    let mut out = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for shape in shapes {
        let numel: usize = shape.iter().product();
        out.push(Tensor::from_vec(shape, buf[off..off + numel].to_vec()));
        off += numel;
    }
    out
}

/// Fold per-rank contributions flat, left-associated, in rank order —
/// byte-for-byte the arithmetic of the PS sync fold
/// (`ps::server::fold_sync_*`): dense adds via `axpy(1.0)`, sparse and
/// quant8 bodies via `scatter_axpy(1.0)` into a zeroed accumulator.
fn fold_rank_order(
    shapes: &[Vec<usize>],
    per_rank: &[Vec<Contrib>],
) -> Result<Vec<Tensor>, String> {
    let mut out = Vec::with_capacity(shapes.len());
    for (k, shape) in shapes.iter().enumerate() {
        let numel: usize = shape.iter().product();
        let mut sum: Option<Tensor> = None;
        for (r, contribs) in per_rank.iter().enumerate() {
            let c = contribs
                .get(k)
                .ok_or_else(|| format!("rank {r}: missing contribution for key {k}"))?;
            match c {
                Contrib::Dense(t) => {
                    if t.shape() != &shape[..] {
                        return Err(format!("rank {r}: key {k} shape mismatch"));
                    }
                    match &mut sum {
                        None => sum = Some(t.clone()),
                        Some(s) => s.axpy(1.0, t),
                    }
                }
                Contrib::Comp(c) => {
                    c.validate(numel).map_err(|e| format!("rank {r} key {k}: {e}"))?;
                    let s = sum.get_or_insert_with(|| Tensor::zeros(shape));
                    c.scatter_axpy(1.0, s.data_mut())
                        .map_err(|e| format!("rank {r} key {k}: {e}"))?;
                }
            }
        }
        out.push(sum.unwrap_or_else(|| Tensor::zeros(shape)));
    }
    Ok(out)
}

fn read_chunk_into(
    body: &[u8],
    step: u64,
    phase: u8,
    seg: usize,
    chunk: usize,
    dst: &mut [f32],
    accumulate: bool,
) -> Result<(), String> {
    let mut r = Reader::new(body);
    if r.u8()? != F_CHUNK {
        return Err("collective desync: expected chunk frame".into());
    }
    if r.u64()? != step || r.u8()? != phase {
        return Err("collective desync: chunk from wrong step/phase".into());
    }
    if r.u32()? as usize != seg || r.u32()? as usize != chunk {
        return Err("collective desync: unexpected segment/chunk index".into());
    }
    let n = r.u32()? as usize;
    if n != dst.len() {
        return Err(format!("collective desync: chunk of {n} floats, expected {}", dst.len()));
    }
    let raw = r.raw(4 * n)?;
    if accumulate {
        for (d, b) in dst.iter_mut().zip(raw.chunks_exact(4)) {
            *d += f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
    } else {
        for (d, b) in dst.iter_mut().zip(raw.chunks_exact(4)) {
            *d = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
    }
    if r.remaining() != 0 {
        return Err("collective desync: trailing bytes in chunk".into());
    }
    Ok(())
}

fn encode_contrib(step: u64, owner: u32, entries: &[Contrib]) -> Vec<u8> {
    let mut w = Writer::with_capacity(64);
    w.u8(F_CONTRIB);
    w.u64(step);
    w.u32(owner);
    w.u32(entries.len() as u32);
    for (k, c) in entries.iter().enumerate() {
        w.u32(k as u32);
        match c {
            Contrib::Dense(t) => {
                w.u8(K_DENSE);
                w.u32(t.len() as u32);
                w.f32_raw(t.data());
            }
            Contrib::Comp(Compressed::Sparse { numel, idx, val }) => {
                w.u8(K_SPARSE);
                w.u32(*numel as u32);
                w.u32(idx.len() as u32);
                w.u32_raw(idx);
                w.f32_raw(val);
            }
            Contrib::Comp(Compressed::Quant8 { numel, scale, q }) => {
                w.u8(K_QUANT8);
                w.u32(*numel as u32);
                w.u32(q.len() as u32);
                w.f32(*scale);
                // SAFETY: i8 and u8 have identical size/alignment and
                // every bit pattern is valid — one bulk append.
                let bytes =
                    unsafe { std::slice::from_raw_parts(q.as_ptr().cast::<u8>(), q.len()) };
                w.raw(bytes);
            }
        }
    }
    w.finish()
}

fn decode_contrib(
    body: &[u8],
    step: u64,
    shapes: &[Vec<usize>],
) -> Result<(u32, Vec<Contrib>), String> {
    let mut r = Reader::new(body);
    if r.u8()? != F_CONTRIB {
        return Err("collective desync: expected contribution frame".into());
    }
    if r.u64()? != step {
        return Err("collective desync: contribution from wrong step".into());
    }
    let owner = r.u32()?;
    let n = r.u32()? as usize;
    if n != shapes.len() {
        return Err(format!("contribution with {n} entries, expected {}", shapes.len()));
    }
    let mut entries = Vec::with_capacity(n);
    for (k, shape) in shapes.iter().enumerate() {
        if r.u32()? as usize != k {
            return Err("collective desync: contribution keys out of order".into());
        }
        let expect: usize = shape.iter().product();
        let kind = r.u8()?;
        let numel = r.u32()? as usize;
        if numel != expect {
            return Err(format!("key {k}: {numel} elements, expected {expect}"));
        }
        match kind {
            K_DENSE => {
                let raw = r.raw(4 * numel)?;
                let mut data = Vec::with_capacity(numel);
                for b in raw.chunks_exact(4) {
                    data.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
                }
                entries.push(Contrib::Dense(Tensor::from_vec(shape, data)));
            }
            K_SPARSE => {
                let nnz = r.u32()? as usize;
                if nnz > numel {
                    return Err(format!("key {k}: {nnz} sparse entries > {numel}"));
                }
                let idx_raw = r.raw(4 * nnz)?;
                let mut idx = Vec::with_capacity(nnz);
                for b in idx_raw.chunks_exact(4) {
                    idx.push(u32::from_le_bytes([b[0], b[1], b[2], b[3]]));
                }
                let val_raw = r.raw(4 * nnz)?;
                let mut val = Vec::with_capacity(nnz);
                for b in val_raw.chunks_exact(4) {
                    val.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
                }
                entries.push(Contrib::Comp(Compressed::Sparse { numel, idx, val }));
            }
            K_QUANT8 => {
                let qlen = r.u32()? as usize;
                if qlen != numel {
                    return Err(format!("key {k}: quant8 qlen {qlen} != numel {numel}"));
                }
                let scale = r.f32()?;
                let raw = r.raw(qlen)?;
                let q: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
                entries.push(Contrib::Comp(Compressed::Quant8 { numel, scale, q }));
            }
            other => return Err(format!("unknown contribution kind {other}")),
        }
    }
    if r.remaining() != 0 {
        return Err("collective desync: trailing bytes in contribution".into());
    }
    Ok((owner, entries))
}

fn encode_sum(step: u64, sums: &[Tensor]) -> Vec<u8> {
    let mut w = Writer::with_capacity(64);
    w.u8(F_SUM);
    w.u64(step);
    w.u32(sums.len() as u32);
    for t in sums {
        w.u32(t.len() as u32);
        w.f32_raw(t.data());
    }
    w.finish()
}

fn decode_sum(body: &[u8], step: u64, shapes: &[Vec<usize>]) -> Result<Vec<Tensor>, String> {
    let mut r = Reader::new(body);
    if r.u8()? != F_SUM {
        return Err("collective desync: expected sum frame".into());
    }
    if r.u64()? != step {
        return Err("collective desync: sum from wrong step".into());
    }
    let n = r.u32()? as usize;
    if n != shapes.len() {
        return Err(format!("sum with {n} entries, expected {}", shapes.len()));
    }
    let mut out = Vec::with_capacity(n);
    for shape in shapes {
        let expect: usize = shape.iter().product();
        let numel = r.u32()? as usize;
        if numel != expect {
            return Err(format!("sum entry of {numel} elements, expected {expect}"));
        }
        let raw = r.raw(4 * numel)?;
        let mut data = Vec::with_capacity(numel);
        for b in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        out.push(Tensor::from_vec(shape, data));
    }
    if r.remaining() != 0 {
        return Err("collective desync: trailing bytes in sum".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::compress::quantize8;
    use crate::util::rng::Rng;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![3], vec![2, 2], vec![5]]
    }

    /// Per-rank dense contributions with integer values, so any
    /// association of the f32 sum is exact and comparable bitwise.
    fn int_contribs(rank: usize, shapes: &[Vec<usize>]) -> Vec<Contrib> {
        shapes
            .iter()
            .enumerate()
            .map(|(k, s)| {
                let numel: usize = s.iter().product();
                let data: Vec<f32> =
                    (0..numel).map(|i| ((rank + 1) * (i + 3 * k + 1)) as f32).collect();
                Contrib::Dense(Tensor::from_vec(s, data))
            })
            .collect()
    }

    fn run_ranks(
        n: usize,
        topology: Topology,
        make: impl Fn(usize) -> Vec<Contrib> + Sync,
    ) -> Vec<Result<Vec<Tensor>, String>> {
        let mesh = inproc_mesh(n);
        let shapes = shapes();
        let mut out: Vec<Result<Vec<Tensor>, String>> = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, links)| {
                    let shapes = shapes.clone();
                    let make = &make;
                    s.spawn(move || {
                        let mut c = Collective::new(rank, n, links, topology, shapes)?;
                        c.set_deadline(Duration::from_secs(5))?;
                        c.allreduce_sum(7, make(rank))
                    })
                })
                .collect();
            for h in handles {
                out.push(h.join().unwrap());
            }
        });
        out
    }

    fn flat_fold(n: usize, make: impl Fn(usize) -> Vec<Contrib>) -> Vec<Tensor> {
        let per_rank: Vec<Vec<Contrib>> = (0..n).map(&make).collect();
        fold_rank_order(&shapes(), &per_rank).unwrap()
    }

    #[test]
    fn ring_dense_sums_exactly() {
        let n = 4;
        let expect = flat_fold(n, |r| int_contribs(r, &shapes()));
        for res in run_ranks(n, Topology::Ring, |r| int_contribs(r, &shapes())) {
            assert_eq!(res.unwrap(), expect);
        }
    }

    #[test]
    fn tree_matches_flat_fold_bitwise() {
        // Arbitrary (non-integer) values: the tree fold is the flat
        // rank-order fold, so equality is bitwise, not just numeric.
        let n = 5;
        let make = |rank: usize| -> Vec<Contrib> {
            let mut rng = Rng::new(0xABCD + rank as u64);
            shapes()
                .iter()
                .map(|s| {
                    let numel: usize = s.iter().product();
                    let data: Vec<f32> =
                        (0..numel).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
                    Contrib::Dense(Tensor::from_vec(s, data))
                })
                .collect()
        };
        let expect = flat_fold(n, make);
        for res in run_ranks(n, Topology::Tree, make) {
            assert_eq!(res.unwrap(), expect);
        }
    }

    #[test]
    fn ring_compressed_relay_matches_flat_fold() {
        let n = 3;
        let make = |rank: usize| -> Vec<Contrib> {
            shapes()
                .iter()
                .enumerate()
                .map(|(k, s)| {
                    let numel: usize = s.iter().product();
                    let data: Vec<f32> =
                        (0..numel).map(|i| (rank as f32 + 1.0) * (i as f32 - k as f32)).collect();
                    Contrib::Comp(quantize8(&Tensor::from_vec(s, data), None))
                })
                .collect()
        };
        let expect = flat_fold(n, make);
        for res in run_ranks(n, Topology::Ring, make) {
            assert_eq!(res.unwrap(), expect);
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let shapes = shapes();
        let mine = int_contribs(0, &shapes);
        let mut c = Collective::new(
            0,
            1,
            vec![None],
            Topology::Ring,
            shapes.clone(),
        )
        .unwrap();
        let out = c.allreduce_sum(0, mine.clone()).unwrap();
        for (got, want) in out.iter().zip(mine.iter()) {
            let Contrib::Dense(t) = want else { panic!() };
            assert_eq!(got, t);
        }
    }

    #[test]
    fn wedged_peer_errors_within_deadline() {
        // Rank 1 of 3 never shows up: the survivors' collective calls
        // must fail within the read deadline, never hang — on every
        // topology.
        let n = 3;
        for topology in [Topology::Ring, Topology::Tree, Topology::Hd] {
            let mut mesh = inproc_mesh(n);
            let links2 = mesh.pop().unwrap();
            let _links1 = mesh.pop().unwrap(); // rank 1 wedged (links held open)
            let links0 = mesh.pop().unwrap();
            let shp = shapes();
            std::thread::scope(|s| {
                for (rank, links) in [(0usize, links0), (2usize, links2)] {
                    let shp = shp.clone();
                    s.spawn(move || {
                        let mut c =
                            Collective::new(rank, n, links, topology, shp.clone()).unwrap();
                        c.set_deadline(Duration::from_millis(200)).unwrap();
                        let res = c.allreduce_sum(0, int_contribs(rank, &shp));
                        assert!(
                            res.is_err(),
                            "rank {rank} should fail on wedged peer ({topology:?})"
                        );
                    });
                }
            });
        }
    }

    #[test]
    fn hd_sums_exactly() {
        // Power-of-two and extra-rank group sizes, integer values so
        // any association of the f32 sum is exact.
        for n in [2usize, 4, 5] {
            let expect = flat_fold(n, |r| int_contribs(r, &shapes()));
            for res in run_ranks(n, Topology::Hd, |r| int_contribs(r, &shapes())) {
                assert_eq!(res.unwrap(), expect, "n={n}");
            }
        }
    }

    #[test]
    fn hd_ranks_agree_bitwise() {
        // Arbitrary float values: every rank must return the exact
        // same bytes (each span is finished by one rank, then copied).
        let n = 6;
        let make = |rank: usize| -> Vec<Contrib> {
            let mut rng = Rng::new(0x5EED + rank as u64);
            shapes()
                .iter()
                .map(|s| {
                    let numel: usize = s.iter().product();
                    let data: Vec<f32> =
                        (0..numel).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
                    Contrib::Dense(Tensor::from_vec(s, data))
                })
                .collect()
        };
        let out: Vec<Vec<Tensor>> =
            run_ranks(n, Topology::Hd, make).into_iter().map(|r| r.unwrap()).collect();
        for got in &out[1..] {
            assert_eq!(got, &out[0]);
        }
    }

    #[test]
    fn hd_compressed_matches_flat_fold() {
        // Compressed contributions fall back to the rank-order relay:
        // bitwise-identical to the compressed ring / PS sync fold.
        let n = 3;
        let make = |rank: usize| -> Vec<Contrib> {
            shapes()
                .iter()
                .enumerate()
                .map(|(k, s)| {
                    let numel: usize = s.iter().product();
                    let data: Vec<f32> =
                        (0..numel).map(|i| (rank as f32 + 1.0) * (i as f32 - k as f32)).collect();
                    Contrib::Comp(quantize8(&Tensor::from_vec(s, data), None))
                })
                .collect()
        };
        let expect = flat_fold(n, make);
        for res in run_ranks(n, Topology::Hd, make) {
            assert_eq!(res.unwrap(), expect);
        }
    }

    #[test]
    fn subset_allreduce_matches_per_key_sums() {
        // The bucketized entry point: reduce keys [0, 2] only, under a
        // caller-chosen tag, and get exactly those keys' sums back.
        let n = 3;
        let shp = shapes();
        let keys = [0usize, 2];
        let full = flat_fold(n, |r| int_contribs(r, &shp));
        let mesh = inproc_mesh(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, links)| {
                    let shp = shp.clone();
                    s.spawn(move || {
                        let mut c =
                            Collective::new(rank, n, links, Topology::Ring, shp.clone()).unwrap();
                        c.set_deadline(Duration::from_secs(5)).unwrap();
                        let all = int_contribs(rank, &shp);
                        let mine: Vec<Contrib> = all
                            .into_iter()
                            .enumerate()
                            .filter(|(k, _)| keys.contains(k))
                            .map(|(_, c)| c)
                            .collect();
                        c.allreduce_sum_keys((9 << 16) | 1, &keys, mine).unwrap()
                    })
                })
                .collect();
            for h in handles {
                let got = h.join().unwrap();
                assert_eq!(got.len(), keys.len());
                for (i, &k) in keys.iter().enumerate() {
                    assert_eq!(got[i], full[k]);
                }
            }
        });
    }

    #[test]
    fn bad_key_sets_are_rejected() {
        let shp = shapes();
        let mut c = Collective::new(0, 1, vec![None], Topology::Ring, shp.clone()).unwrap();
        let mine = vec![Contrib::Dense(Tensor::zeros(&shp[0]))];
        assert!(c.allreduce_sum_keys(0, &[7], mine.clone()).is_err(), "out of range");
        let two = vec![
            Contrib::Dense(Tensor::zeros(&shp[1])),
            Contrib::Dense(Tensor::zeros(&shp[0])),
        ];
        assert!(c.allreduce_sum_keys(0, &[1, 0], two).is_err(), "not ascending");
        assert!(c.allreduce_sum_keys(0, &[0, 1], mine).is_err(), "count mismatch");
    }

    #[test]
    fn scaled_deadline_grows_with_chunks_and_buckets() {
        let base = Duration::from_millis(100);
        let cap = Duration::from_secs(60);
        // One chunk, one bucket: the base.
        assert_eq!(scaled_deadline(base, cap, 16384, 100, 1), base);
        // Eight chunks: 8x the base.
        assert_eq!(
            scaled_deadline(base, cap, 16384, 16384 * 8, 1),
            Duration::from_millis(800)
        );
        // Four buckets in flight multiply again.
        assert_eq!(
            scaled_deadline(base, cap, 16384, 16384 * 8, 4),
            Duration::from_millis(3200)
        );
        // The cap bounds runaway scaling.
        assert_eq!(scaled_deadline(base, cap, 1, usize::MAX, 64), cap);
    }

    #[test]
    fn hd_span_partitions_the_buffer() {
        // At s_min = 1 the p spans tile [0, len) in rank order of the
        // bit-reversal walk — verify they are disjoint and complete.
        let (len, p) = (103usize, 8usize);
        let mut covered = vec![false; len];
        for r in 0..p {
            let (lo, hi) = hd_span(len, p, r, 1);
            for c in &mut covered[lo..hi] {
                assert!(!*c, "overlap at rank {r}");
                *c = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "spans must cover the buffer");
    }

    #[test]
    fn wire_byte_counters_split_by_direction() {
        let n = 2;
        let out = run_counters(n);
        for (reduce, bcast) in out {
            assert!(reduce > 0, "reduce bytes counted");
            assert!(bcast > 0, "bcast bytes counted");
        }
    }

    fn run_counters(n: usize) -> Vec<(u64, u64)> {
        let mesh = inproc_mesh(n);
        let shp = shapes();
        let mut out = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, links)| {
                    let shp = shp.clone();
                    s.spawn(move || {
                        let mut c =
                            Collective::new(rank, n, links, Topology::Ring, shp.clone()).unwrap();
                        c.allreduce_sum(1, int_contribs(rank, &shp)).unwrap();
                        (c.reduce_wire_bytes(), c.bcast_wire_bytes())
                    })
                })
                .collect();
            for h in handles {
                out.push(h.join().unwrap());
            }
        });
        out
    }
}
