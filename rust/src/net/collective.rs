//! Peer-to-peer collective aggregation: ring + tree allreduce.
//!
//! The second data-parallel backend (`train-dist --backend allreduce`)
//! replaces the parameter-server fleet with a worker-only collective:
//! every rank holds a full model replica, and each step the ranks
//! allreduce their gradient contributions and apply the identical mean
//! locally. FireCaffe (arXiv:1511.00175) showed reduction trees beating
//! parameter servers at scale; Shi et al. (arXiv:1711.05979) model the
//! PS-vs-allreduce trade-off this module realizes — see
//! `advisor::lemmas::choose_backend` for the cost model that picks a
//! side.
//!
//! # Topologies
//!
//! * **Ring, dense** — the classic chunked ring allreduce:
//!   reduce-scatter (N−1 rounds, each rank accumulates one segment)
//!   then allgather (N−1 rounds, the finished segments circulate).
//!   Per-rank traffic is `2 (N−1)/N · S` regardless of N — bandwidth
//!   optimal. Segment sums accumulate in ring order, so the result is a
//!   *sum* with ring-rotation association (identical bytes on every
//!   rank, since each segment is finished exactly once and then
//!   copied).
//! * **Ring, compressed** — codecs are per-key, stateful (top-k error
//!   feedback) and non-linear, so compressed bodies cannot be summed
//!   mid-ring. Instead each rank compresses its own gradient once and
//!   the *contributions* relay around the ring verbatim (N−1 hops);
//!   every rank then folds all N contributions **flat, in rank order**
//!   — the same left-associated accumulation the PS sync fold uses, so
//!   identical inputs produce bit-identical sums.
//! * **Tree** — contributions stream up a binary tree to the root
//!   (rank 0), which folds them flat in rank order — again exactly the
//!   PS fold — and broadcasts the dense sum back down. Every rank
//!   applies the root's bytes, so the replicas stay bit-identical.
//!   Latency is `O(log N)` rounds; the root pays `O(N·S)` inbound.
//!
//! # Fault behavior
//!
//! Collectives hang when a peer wedges — unless every receive is
//! bounded. All links carry a read deadline (default
//! [`DEFAULT_DEADLINE_MS`]); a dropped, severed or wedged peer turns
//! into a clean `Err` from the collective call, which the coordinator's
//! reform loop (`coordinator::distributed::run_allreduce`) handles by
//! rebuilding the group from the surviving ranks' committed state. A
//! collective op never blocks forever — chaos-tested with
//! `net::fault::FaultyTransport` in `tests/chaos.rs`.
//!
//! # Wire format
//!
//! Collective links are private rank-to-rank connections; their frames
//! use tags ≥ 40, disjoint from `net::message` (which owns 1..=26), and
//! never pass through `Message::decode`:
//!
//! | frame | payload |
//! |-------|---------|
//! | chunk (40) | `u64 step, u8 phase, u32 seg, u32 chunk, u32 n, n × f32` |
//! | contribution (41) | `u64 step, u32 owner, u32 n, n × (u32 key, u8 kind, body)` |
//! | dense sum (42) | `u64 step, u32 n, n × (u32 numel, numel × f32)` |
//!
//! Contribution bodies: kind 0 = dense (`u32 numel, numel × f32`),
//! kind 1 = sparse top-k (`u32 numel, u32 k, k × u32 idx, k × f32
//! val`), kind 2 = quant8 (`u32 numel, u32 qlen, f32 scale, qlen ×
//! i8`) — the compressed bodies byte-match the `CompressedPush` entry
//! bodies, so the advisor's traffic accounting transfers unchanged.

use std::time::Duration;

use crate::net::codec::{Reader, Writer};
use crate::net::transport::{InProcTransport, Transport};
use crate::ps::compress::Compressed;
use crate::tensor::Tensor;

/// Frame tags for collective links (disjoint from `net::message`).
const F_CHUNK: u8 = 40;
const F_CONTRIB: u8 = 41;
const F_SUM: u8 = 42;

/// Contribution-entry kind bytes.
const K_DENSE: u8 = 0;
const K_SPARSE: u8 = 1;
const K_QUANT8: u8 = 2;

/// Ring phase bytes (desync detection).
const P_REDUCE: u8 = 0;
const P_GATHER: u8 = 1;

/// Default floats per ring chunk (64 KiB frames): big enough to
/// amortize framing, small enough to pipeline send/recv and never
/// deadlock head-to-head TCP sends.
pub const DEFAULT_CHUNK_FLOATS: usize = 16_384;

/// Default per-receive deadline on collective links. A wedged peer
/// surfaces as an `Err` within this bound instead of hanging the
/// collective.
pub const DEFAULT_DEADLINE_MS: u64 = 5_000;

/// Collective topology. `Ring` is bandwidth-optimal; `Tree` is
/// latency-optimal — `advisor::lemmas::choose_backend` picks from the
/// Lemma 3.2 inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    Ring,
    Tree,
}

impl Topology {
    pub fn parse(s: &str) -> Result<Topology, String> {
        match s {
            "ring" => Ok(Topology::Ring),
            "tree" => Ok(Topology::Tree),
            other => Err(format!("unknown topology {other:?} (ring|tree)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Topology::Ring => "ring",
            Topology::Tree => "tree",
        }
    }
}

/// One rank's per-key gradient contribution: dense, or compressed by
/// the push codec (the exact same [`Compressed`] the PS client would
/// have put on the wire).
#[derive(Debug, Clone, PartialEq)]
pub enum Contrib {
    Dense(Tensor),
    Comp(Compressed),
}

/// One rank's links to its peers, indexed by peer rank (`None` at the
/// rank's own slot).
pub type Links = Vec<Option<Box<dyn Transport>>>;

/// Build a full in-process mesh: `mesh(n)[i][j]` is rank `i`'s link to
/// rank `j`. The run path wraps these in `FaultyTransport` for chaos
/// runs; ring/tree only use the neighbor/parent-child subset.
pub fn inproc_mesh(n: usize) -> Vec<Links> {
    let mut rows: Vec<Links> = (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
    for i in 0..n {
        for j in (i + 1)..n {
            let (a, b) = InProcTransport::pair();
            rows[i][j] = Some(Box::new(a) as Box<dyn Transport>);
            rows[j][i] = Some(Box::new(b) as Box<dyn Transport>);
        }
    }
    rows
}

fn subtree_size(n: usize, i: usize) -> usize {
    if i >= n {
        0
    } else {
        1 + subtree_size(n, 2 * i + 1) + subtree_size(n, 2 * i + 2)
    }
}

/// One rank's handle on the collective group: its links, the model's
/// key shapes (every rank holds the full model), and wire-byte
/// counters split by direction — `reduce` (reduce-scatter / relay /
/// gather-up, the push-direction analogue) and `bcast` (allgather /
/// broadcast-down, the pull-direction analogue).
pub struct Collective {
    rank: usize,
    n: usize,
    links: Links,
    topology: Topology,
    shapes: Vec<Vec<usize>>,
    chunk_floats: usize,
    reduce_bytes: u64,
    bcast_bytes: u64,
}

impl Collective {
    pub fn new(
        rank: usize,
        n: usize,
        mut links: Links,
        topology: Topology,
        shapes: Vec<Vec<usize>>,
    ) -> Result<Collective, String> {
        if n == 0 || rank >= n {
            return Err(format!("bad collective rank {rank} of {n}"));
        }
        if links.len() != n {
            return Err(format!("rank {rank}: {} links for {n} ranks", links.len()));
        }
        if links[rank].is_some() {
            return Err(format!("rank {rank}: self-link present"));
        }
        let d = Duration::from_millis(DEFAULT_DEADLINE_MS);
        for l in links.iter_mut().flatten() {
            l.set_read_deadline(Some(d))?;
        }
        Ok(Collective {
            rank,
            n,
            links,
            topology,
            shapes,
            chunk_floats: DEFAULT_CHUNK_FLOATS,
            reduce_bytes: 0,
            bcast_bytes: 0,
        })
    }

    /// Bound every receive on this rank's links. The collective's
    /// liveness guarantee — a wedged peer is an `Err`, never a hang —
    /// is exactly this deadline.
    pub fn set_deadline(&mut self, d: Duration) -> Result<(), String> {
        for l in self.links.iter_mut().flatten() {
            l.set_read_deadline(Some(d))?;
        }
        Ok(())
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n_ranks(&self) -> usize {
        self.n
    }

    /// Bytes this rank sent in the reduce direction (reduce-scatter,
    /// contribution relay, gather-up).
    pub fn reduce_wire_bytes(&self) -> u64 {
        self.reduce_bytes
    }

    /// Bytes this rank sent in the broadcast direction (allgather,
    /// broadcast-down).
    pub fn bcast_wire_bytes(&self) -> u64 {
        self.bcast_bytes
    }

    fn link(&mut self, peer: usize) -> Result<&mut Box<dyn Transport>, String> {
        self.links
            .get_mut(peer)
            .and_then(|l| l.as_mut())
            .ok_or_else(|| format!("no link to rank {peer}"))
    }

    /// Allreduce this step's contributions into the per-key **sum**
    /// over all ranks (callers scale by `1/N` — the same
    /// scale-then-apply the PS sync release performs). Every rank
    /// returns bit-identical tensors. Errors are clean and bounded:
    /// a dead or wedged peer fails the call within the read deadline.
    pub fn allreduce_sum(
        &mut self,
        step: u64,
        mine: Vec<Contrib>,
    ) -> Result<Vec<Tensor>, String> {
        if mine.len() != self.shapes.len() {
            return Err(format!(
                "rank {}: {} contributions for {} keys",
                self.rank,
                mine.len(),
                self.shapes.len()
            ));
        }
        if self.n == 1 {
            let shapes = self.shapes.clone();
            return fold_rank_order(&shapes, &[mine]);
        }
        let all_dense = mine.iter().all(|c| matches!(c, Contrib::Dense(_)));
        match self.topology {
            Topology::Ring if all_dense => self.ring_dense(step, mine),
            Topology::Ring => self.ring_relay(step, mine),
            Topology::Tree => self.tree_sum(step, mine),
        }
    }

    // ---- dense ring: chunked reduce-scatter + allgather ------------

    fn ring_dense(&mut self, step: u64, mine: Vec<Contrib>) -> Result<Vec<Tensor>, String> {
        let mut buf = Vec::new();
        for (k, c) in mine.iter().enumerate() {
            let Contrib::Dense(t) = c else { unreachable!() };
            if t.shape() != &self.shapes[k][..] {
                return Err(format!("rank {}: key {k} shape mismatch", self.rank));
            }
            buf.extend_from_slice(t.data());
        }
        let n = self.n;
        // Reduce-scatter: after round r this rank has accumulated r+2
        // contributions into segment (rank - r - 1) mod n; after n-1
        // rounds it owns the finished segment (rank + 1) mod n.
        for r in 0..n - 1 {
            let send_seg = (self.rank + n - r) % n;
            let recv_seg = (self.rank + n - r - 1) % n;
            self.exchange_seg(step, P_REDUCE, send_seg, recv_seg, &mut buf, true)?;
        }
        // Allgather: finished segments circulate; receives overwrite.
        for r in 0..n - 1 {
            let send_seg = (self.rank + 1 + n - r) % n;
            let recv_seg = (self.rank + n - r) % n;
            self.exchange_seg(step, P_GATHER, send_seg, recv_seg, &mut buf, false)?;
        }
        // Unflatten back into per-key tensors.
        let mut out = Vec::with_capacity(self.shapes.len());
        let mut off = 0;
        for shape in &self.shapes {
            let numel: usize = shape.iter().product();
            out.push(Tensor::from_vec(shape, buf[off..off + numel].to_vec()));
            off += numel;
        }
        Ok(out)
    }

    fn seg_bounds(&self, len: usize, seg: usize) -> (usize, usize) {
        (seg * len / self.n, (seg + 1) * len / self.n)
    }

    /// One ring round: send `send_seg` to the right neighbor while
    /// receiving `recv_seg` from the left, chunk-interleaved so neither
    /// side ever has more than one chunk outstanding past the socket
    /// buffer (no head-to-head send deadlock over TCP).
    fn exchange_seg(
        &mut self,
        step: u64,
        phase: u8,
        send_seg: usize,
        recv_seg: usize,
        buf: &mut [f32],
        accumulate: bool,
    ) -> Result<(), String> {
        let right = (self.rank + 1) % self.n;
        let left = (self.rank + self.n - 1) % self.n;
        let (ss, se) = self.seg_bounds(buf.len(), send_seg);
        let (rs, re) = self.seg_bounds(buf.len(), recv_seg);
        let chunk = self.chunk_floats.max(1);
        let n_send = (se - ss).div_ceil(chunk);
        let n_recv = (re - rs).div_ceil(chunk);
        for k in 0..n_send.max(n_recv) {
            if k < n_send {
                let a = ss + k * chunk;
                let b = (a + chunk).min(se);
                let slice = &buf[a..b];
                let (seg32, k32, n32) = (send_seg as u32, k as u32, slice.len() as u32);
                self.link(right)?.send_with(&mut |w: &mut Writer| {
                    w.u8(F_CHUNK);
                    w.u64(step);
                    w.u8(phase);
                    w.u32(seg32);
                    w.u32(k32);
                    w.u32(n32);
                    w.f32_raw(slice);
                })?;
                let sent = 22 + 4 * (b - a) as u64;
                if phase == P_REDUCE {
                    self.reduce_bytes += sent;
                } else {
                    self.bcast_bytes += sent;
                }
            }
            if k < n_recv {
                let a = rs + k * chunk;
                let b = (a + chunk).min(re);
                let dst = &mut buf[a..b];
                let mut res: Result<(), String> = Ok(());
                self.links[left]
                    .as_mut()
                    .ok_or_else(|| format!("no link to rank {left}"))?
                    .recv_with(&mut |body: &[u8]| {
                        res = read_chunk_into(body, step, phase, recv_seg, k, dst, accumulate);
                        Ok(())
                    })?;
                res?;
            }
        }
        Ok(())
    }

    // ---- compressed ring: contribution relay -----------------------

    fn ring_relay(&mut self, step: u64, mine: Vec<Contrib>) -> Result<Vec<Tensor>, String> {
        let n = self.n;
        let right = (self.rank + 1) % n;
        let left = (self.rank + n - 1) % n;
        // Send own contribution once; it relays all the way around.
        let own = encode_contrib(step, self.rank as u32, &mine);
        self.link(right)?.send_with(&mut |w: &mut Writer| w.raw(&own))?;
        self.reduce_bytes += own.len() as u64;
        let mut per_rank: Vec<Option<Vec<Contrib>>> = (0..n).map(|_| None).collect();
        per_rank[self.rank] = Some(mine);
        for r in 0..n - 1 {
            let expect_owner = (self.rank + n - 1 - r) % n;
            let mut frame = Vec::new();
            self.links[left]
                .as_mut()
                .ok_or_else(|| format!("no link to rank {left}"))?
                .recv_with(&mut |body: &[u8]| {
                    frame.extend_from_slice(body);
                    Ok(())
                })?;
            let (owner, entries) = decode_contrib(&frame, step, &self.shapes)?;
            if owner as usize != expect_owner {
                return Err(format!(
                    "collective desync: contribution from rank {owner}, expected {expect_owner}"
                ));
            }
            // Relay unless the right neighbor is the owner (frame has
            // then completed its loop).
            if right != owner as usize {
                self.link(right)?.send_with(&mut |w: &mut Writer| w.raw(&frame))?;
                self.reduce_bytes += frame.len() as u64;
            }
            per_rank[owner as usize] = Some(entries);
        }
        let ordered: Vec<Vec<Contrib>> = per_rank
            .into_iter()
            .map(|c| c.ok_or_else(|| "collective desync: missing contribution".to_string()))
            .collect::<Result<_, _>>()?;
        let shapes = self.shapes.clone();
        fold_rank_order(&shapes, &ordered)
    }

    // ---- tree: gather contributions to root, broadcast dense sum ---

    fn tree_sum(&mut self, step: u64, mine: Vec<Contrib>) -> Result<Vec<Tensor>, String> {
        let n = self.n;
        let parent = if self.rank == 0 { None } else { Some((self.rank - 1) / 2) };
        let children: Vec<usize> =
            [2 * self.rank + 1, 2 * self.rank + 2].into_iter().filter(|&c| c < n).collect();
        // Gather up: own contribution first, then relay each child's
        // subtree verbatim. The root decodes everything.
        let mut per_rank: Vec<Option<Vec<Contrib>>> = (0..n).map(|_| None).collect();
        if let Some(p) = parent {
            let own = encode_contrib(step, self.rank as u32, &mine);
            self.link(p)?.send_with(&mut |w: &mut Writer| w.raw(&own))?;
            self.reduce_bytes += own.len() as u64;
        }
        per_rank[self.rank] = Some(mine);
        for &c in &children {
            for _ in 0..subtree_size(n, c) {
                let mut frame = Vec::new();
                self.links[c]
                    .as_mut()
                    .ok_or_else(|| format!("no link to rank {c}"))?
                    .recv_with(&mut |body: &[u8]| {
                        frame.extend_from_slice(body);
                        Ok(())
                    })?;
                if let Some(p) = parent {
                    self.link(p)?.send_with(&mut |w: &mut Writer| w.raw(&frame))?;
                    self.reduce_bytes += frame.len() as u64;
                } else {
                    let (owner, entries) = decode_contrib(&frame, step, &self.shapes)?;
                    if (owner as usize) >= n || per_rank[owner as usize].is_some() {
                        return Err(format!(
                            "collective desync: duplicate contribution from rank {owner}"
                        ));
                    }
                    per_rank[owner as usize] = Some(entries);
                }
            }
        }
        // Root folds flat in rank order — the exact PS sync fold — and
        // broadcasts the dense sum; everyone applies the same bytes.
        let sums = if parent.is_none() {
            let ordered: Vec<Vec<Contrib>> = per_rank
                .into_iter()
                .map(|c| c.ok_or_else(|| "collective desync: missing contribution".to_string()))
                .collect::<Result<_, _>>()?;
            let shapes = self.shapes.clone();
            fold_rank_order(&shapes, &ordered)?
        } else {
            let p = parent.unwrap();
            let mut frame = Vec::new();
            self.links[p]
                .as_mut()
                .ok_or_else(|| format!("no link to rank {p}"))?
                .recv_with(&mut |body: &[u8]| {
                    frame.extend_from_slice(body);
                    Ok(())
                })?;
            decode_sum(&frame, step, &self.shapes)?
        };
        if !children.is_empty() {
            let frame = encode_sum(step, &sums);
            for &c in &children {
                self.link(c)?.send_with(&mut |w: &mut Writer| w.raw(&frame))?;
                self.bcast_bytes += frame.len() as u64;
            }
        }
        Ok(sums)
    }
}

/// Fold per-rank contributions flat, left-associated, in rank order —
/// byte-for-byte the arithmetic of the PS sync fold
/// (`ps::server::fold_sync_*`): dense adds via `axpy(1.0)`, sparse and
/// quant8 bodies via `scatter_axpy(1.0)` into a zeroed accumulator.
fn fold_rank_order(
    shapes: &[Vec<usize>],
    per_rank: &[Vec<Contrib>],
) -> Result<Vec<Tensor>, String> {
    let mut out = Vec::with_capacity(shapes.len());
    for (k, shape) in shapes.iter().enumerate() {
        let numel: usize = shape.iter().product();
        let mut sum: Option<Tensor> = None;
        for (r, contribs) in per_rank.iter().enumerate() {
            let c = contribs
                .get(k)
                .ok_or_else(|| format!("rank {r}: missing contribution for key {k}"))?;
            match c {
                Contrib::Dense(t) => {
                    if t.shape() != &shape[..] {
                        return Err(format!("rank {r}: key {k} shape mismatch"));
                    }
                    match &mut sum {
                        None => sum = Some(t.clone()),
                        Some(s) => s.axpy(1.0, t),
                    }
                }
                Contrib::Comp(c) => {
                    c.validate(numel).map_err(|e| format!("rank {r} key {k}: {e}"))?;
                    let s = sum.get_or_insert_with(|| Tensor::zeros(shape));
                    c.scatter_axpy(1.0, s.data_mut())
                        .map_err(|e| format!("rank {r} key {k}: {e}"))?;
                }
            }
        }
        out.push(sum.unwrap_or_else(|| Tensor::zeros(shape)));
    }
    Ok(out)
}

fn read_chunk_into(
    body: &[u8],
    step: u64,
    phase: u8,
    seg: usize,
    chunk: usize,
    dst: &mut [f32],
    accumulate: bool,
) -> Result<(), String> {
    let mut r = Reader::new(body);
    if r.u8()? != F_CHUNK {
        return Err("collective desync: expected chunk frame".into());
    }
    if r.u64()? != step || r.u8()? != phase {
        return Err("collective desync: chunk from wrong step/phase".into());
    }
    if r.u32()? as usize != seg || r.u32()? as usize != chunk {
        return Err("collective desync: unexpected segment/chunk index".into());
    }
    let n = r.u32()? as usize;
    if n != dst.len() {
        return Err(format!("collective desync: chunk of {n} floats, expected {}", dst.len()));
    }
    let raw = r.raw(4 * n)?;
    if accumulate {
        for (d, b) in dst.iter_mut().zip(raw.chunks_exact(4)) {
            *d += f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
    } else {
        for (d, b) in dst.iter_mut().zip(raw.chunks_exact(4)) {
            *d = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
    }
    if r.remaining() != 0 {
        return Err("collective desync: trailing bytes in chunk".into());
    }
    Ok(())
}

fn encode_contrib(step: u64, owner: u32, entries: &[Contrib]) -> Vec<u8> {
    let mut w = Writer::with_capacity(64);
    w.u8(F_CONTRIB);
    w.u64(step);
    w.u32(owner);
    w.u32(entries.len() as u32);
    for (k, c) in entries.iter().enumerate() {
        w.u32(k as u32);
        match c {
            Contrib::Dense(t) => {
                w.u8(K_DENSE);
                w.u32(t.len() as u32);
                w.f32_raw(t.data());
            }
            Contrib::Comp(Compressed::Sparse { numel, idx, val }) => {
                w.u8(K_SPARSE);
                w.u32(*numel as u32);
                w.u32(idx.len() as u32);
                w.u32_raw(idx);
                w.f32_raw(val);
            }
            Contrib::Comp(Compressed::Quant8 { numel, scale, q }) => {
                w.u8(K_QUANT8);
                w.u32(*numel as u32);
                w.u32(q.len() as u32);
                w.f32(*scale);
                // SAFETY: i8 and u8 have identical size/alignment and
                // every bit pattern is valid — one bulk append.
                let bytes =
                    unsafe { std::slice::from_raw_parts(q.as_ptr().cast::<u8>(), q.len()) };
                w.raw(bytes);
            }
        }
    }
    w.finish()
}

fn decode_contrib(
    body: &[u8],
    step: u64,
    shapes: &[Vec<usize>],
) -> Result<(u32, Vec<Contrib>), String> {
    let mut r = Reader::new(body);
    if r.u8()? != F_CONTRIB {
        return Err("collective desync: expected contribution frame".into());
    }
    if r.u64()? != step {
        return Err("collective desync: contribution from wrong step".into());
    }
    let owner = r.u32()?;
    let n = r.u32()? as usize;
    if n != shapes.len() {
        return Err(format!("contribution with {n} entries, expected {}", shapes.len()));
    }
    let mut entries = Vec::with_capacity(n);
    for (k, shape) in shapes.iter().enumerate() {
        if r.u32()? as usize != k {
            return Err("collective desync: contribution keys out of order".into());
        }
        let expect: usize = shape.iter().product();
        let kind = r.u8()?;
        let numel = r.u32()? as usize;
        if numel != expect {
            return Err(format!("key {k}: {numel} elements, expected {expect}"));
        }
        match kind {
            K_DENSE => {
                let raw = r.raw(4 * numel)?;
                let mut data = Vec::with_capacity(numel);
                for b in raw.chunks_exact(4) {
                    data.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
                }
                entries.push(Contrib::Dense(Tensor::from_vec(shape, data)));
            }
            K_SPARSE => {
                let nnz = r.u32()? as usize;
                if nnz > numel {
                    return Err(format!("key {k}: {nnz} sparse entries > {numel}"));
                }
                let idx_raw = r.raw(4 * nnz)?;
                let mut idx = Vec::with_capacity(nnz);
                for b in idx_raw.chunks_exact(4) {
                    idx.push(u32::from_le_bytes([b[0], b[1], b[2], b[3]]));
                }
                let val_raw = r.raw(4 * nnz)?;
                let mut val = Vec::with_capacity(nnz);
                for b in val_raw.chunks_exact(4) {
                    val.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
                }
                entries.push(Contrib::Comp(Compressed::Sparse { numel, idx, val }));
            }
            K_QUANT8 => {
                let qlen = r.u32()? as usize;
                if qlen != numel {
                    return Err(format!("key {k}: quant8 qlen {qlen} != numel {numel}"));
                }
                let scale = r.f32()?;
                let raw = r.raw(qlen)?;
                let q: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
                entries.push(Contrib::Comp(Compressed::Quant8 { numel, scale, q }));
            }
            other => return Err(format!("unknown contribution kind {other}")),
        }
    }
    if r.remaining() != 0 {
        return Err("collective desync: trailing bytes in contribution".into());
    }
    Ok((owner, entries))
}

fn encode_sum(step: u64, sums: &[Tensor]) -> Vec<u8> {
    let mut w = Writer::with_capacity(64);
    w.u8(F_SUM);
    w.u64(step);
    w.u32(sums.len() as u32);
    for t in sums {
        w.u32(t.len() as u32);
        w.f32_raw(t.data());
    }
    w.finish()
}

fn decode_sum(body: &[u8], step: u64, shapes: &[Vec<usize>]) -> Result<Vec<Tensor>, String> {
    let mut r = Reader::new(body);
    if r.u8()? != F_SUM {
        return Err("collective desync: expected sum frame".into());
    }
    if r.u64()? != step {
        return Err("collective desync: sum from wrong step".into());
    }
    let n = r.u32()? as usize;
    if n != shapes.len() {
        return Err(format!("sum with {n} entries, expected {}", shapes.len()));
    }
    let mut out = Vec::with_capacity(n);
    for shape in shapes {
        let expect: usize = shape.iter().product();
        let numel = r.u32()? as usize;
        if numel != expect {
            return Err(format!("sum entry of {numel} elements, expected {expect}"));
        }
        let raw = r.raw(4 * numel)?;
        let mut data = Vec::with_capacity(numel);
        for b in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        out.push(Tensor::from_vec(shape, data));
    }
    if r.remaining() != 0 {
        return Err("collective desync: trailing bytes in sum".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::compress::quantize8;
    use crate::util::rng::Rng;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![3], vec![2, 2], vec![5]]
    }

    /// Per-rank dense contributions with integer values, so any
    /// association of the f32 sum is exact and comparable bitwise.
    fn int_contribs(rank: usize, shapes: &[Vec<usize>]) -> Vec<Contrib> {
        shapes
            .iter()
            .enumerate()
            .map(|(k, s)| {
                let numel: usize = s.iter().product();
                let data: Vec<f32> =
                    (0..numel).map(|i| ((rank + 1) * (i + 3 * k + 1)) as f32).collect();
                Contrib::Dense(Tensor::from_vec(s, data))
            })
            .collect()
    }

    fn run_ranks(
        n: usize,
        topology: Topology,
        make: impl Fn(usize) -> Vec<Contrib> + Sync,
    ) -> Vec<Result<Vec<Tensor>, String>> {
        let mesh = inproc_mesh(n);
        let shapes = shapes();
        let mut out: Vec<Result<Vec<Tensor>, String>> = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, links)| {
                    let shapes = shapes.clone();
                    let make = &make;
                    s.spawn(move || {
                        let mut c = Collective::new(rank, n, links, topology, shapes)?;
                        c.set_deadline(Duration::from_secs(5))?;
                        c.allreduce_sum(7, make(rank))
                    })
                })
                .collect();
            for h in handles {
                out.push(h.join().unwrap());
            }
        });
        out
    }

    fn flat_fold(n: usize, make: impl Fn(usize) -> Vec<Contrib>) -> Vec<Tensor> {
        let per_rank: Vec<Vec<Contrib>> = (0..n).map(&make).collect();
        fold_rank_order(&shapes(), &per_rank).unwrap()
    }

    #[test]
    fn ring_dense_sums_exactly() {
        let n = 4;
        let expect = flat_fold(n, |r| int_contribs(r, &shapes()));
        for res in run_ranks(n, Topology::Ring, |r| int_contribs(r, &shapes())) {
            assert_eq!(res.unwrap(), expect);
        }
    }

    #[test]
    fn tree_matches_flat_fold_bitwise() {
        // Arbitrary (non-integer) values: the tree fold is the flat
        // rank-order fold, so equality is bitwise, not just numeric.
        let n = 5;
        let make = |rank: usize| -> Vec<Contrib> {
            let mut rng = Rng::new(0xABCD + rank as u64);
            shapes()
                .iter()
                .map(|s| {
                    let numel: usize = s.iter().product();
                    let data: Vec<f32> =
                        (0..numel).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
                    Contrib::Dense(Tensor::from_vec(s, data))
                })
                .collect()
        };
        let expect = flat_fold(n, make);
        for res in run_ranks(n, Topology::Tree, make) {
            assert_eq!(res.unwrap(), expect);
        }
    }

    #[test]
    fn ring_compressed_relay_matches_flat_fold() {
        let n = 3;
        let make = |rank: usize| -> Vec<Contrib> {
            shapes()
                .iter()
                .enumerate()
                .map(|(k, s)| {
                    let numel: usize = s.iter().product();
                    let data: Vec<f32> =
                        (0..numel).map(|i| (rank as f32 + 1.0) * (i as f32 - k as f32)).collect();
                    Contrib::Comp(quantize8(&Tensor::from_vec(s, data), None))
                })
                .collect()
        };
        let expect = flat_fold(n, make);
        for res in run_ranks(n, Topology::Ring, make) {
            assert_eq!(res.unwrap(), expect);
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let shapes = shapes();
        let mine = int_contribs(0, &shapes);
        let mut c = Collective::new(
            0,
            1,
            vec![None],
            Topology::Ring,
            shapes.clone(),
        )
        .unwrap();
        let out = c.allreduce_sum(0, mine.clone()).unwrap();
        for (got, want) in out.iter().zip(mine.iter()) {
            let Contrib::Dense(t) = want else { panic!() };
            assert_eq!(got, t);
        }
    }

    #[test]
    fn wedged_peer_errors_within_deadline() {
        // Rank 1 of 3 never shows up: the survivors' collective calls
        // must fail within the read deadline, never hang.
        let n = 3;
        let mut mesh = inproc_mesh(n);
        let links2 = mesh.pop().unwrap();
        let _links1 = mesh.pop().unwrap(); // rank 1 wedged (links held open)
        let links0 = mesh.pop().unwrap();
        let shp = shapes();
        std::thread::scope(|s| {
            for (rank, links) in [(0usize, links0), (2usize, links2)] {
                let shp = shp.clone();
                s.spawn(move || {
                    let mut c =
                        Collective::new(rank, n, links, Topology::Ring, shp.clone()).unwrap();
                    c.set_deadline(Duration::from_millis(200)).unwrap();
                    let res = c.allreduce_sum(0, int_contribs(rank, &shp));
                    assert!(res.is_err(), "rank {rank} should fail on wedged peer");
                });
            }
        });
    }

    #[test]
    fn wire_byte_counters_split_by_direction() {
        let n = 2;
        let out = run_counters(n);
        for (reduce, bcast) in out {
            assert!(reduce > 0, "reduce bytes counted");
            assert!(bcast > 0, "bcast bytes counted");
        }
    }

    fn run_counters(n: usize) -> Vec<(u64, u64)> {
        let mesh = inproc_mesh(n);
        let shp = shapes();
        let mut out = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, links)| {
                    let shp = shp.clone();
                    s.spawn(move || {
                        let mut c =
                            Collective::new(rank, n, links, Topology::Ring, shp.clone()).unwrap();
                        c.allreduce_sum(1, int_contribs(rank, &shp)).unwrap();
                        (c.reduce_wire_bytes(), c.bcast_wire_bytes())
                    })
                })
                .collect();
            for h in handles {
                out.push(h.join().unwrap());
            }
        });
        out
    }
}
