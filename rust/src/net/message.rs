//! Parameter-server wire protocol (Fig. 1 steps 1 and 7).
//!
//! Workers `Pull` the latest parameter shard values at the start of a
//! mini-batch (step 1, "parameter refresh") and `Push` gradient deltas
//! after compute (step 7, "distributed update"). `Barrier` supports
//! synchronous SGD; `Stats`/`Shutdown` are control-plane.

use super::codec::{Reader, Writer};
use crate::tensor::Tensor;

/// Protocol messages. `key` identifies a parameter tensor (its index in
/// the artifact manifest); routing to servers is the `ps::router`'s job.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker -> server: request current values of `keys`.
    Pull { worker: u32, keys: Vec<u32> },
    /// Server -> worker: requested values with the server's clock.
    PullReply { clock: u64, entries: Vec<(u32, Tensor)> },
    /// Worker -> server: gradients for `entries` (step `step` at worker).
    Push { worker: u32, step: u64, entries: Vec<(u32, Tensor)> },
    /// Server -> worker: push accepted (async mode acks immediately).
    PushAck { clock: u64 },
    /// Worker -> server: enter sync barrier for `step`.
    Barrier { worker: u32, step: u64 },
    /// Server -> worker: barrier released, proceed to `step`.
    BarrierRelease { step: u64 },
    /// Control: ask the server for counters.
    Stats,
    /// Server -> control: counters.
    StatsReply { pulls: u64, pushes: u64, updates: u64 },
    /// Control: stop serving.
    Shutdown,
    /// Either direction: protocol error.
    Error { what: String },
}

const T_PULL: u8 = 1;
const T_PULL_REPLY: u8 = 2;
const T_PUSH: u8 = 3;
const T_PUSH_ACK: u8 = 4;
const T_BARRIER: u8 = 5;
const T_BARRIER_RELEASE: u8 = 6;
const T_STATS: u8 = 7;
const T_STATS_REPLY: u8 = 8;
const T_SHUTDOWN: u8 = 9;
const T_ERROR: u8 = 10;

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(64);
        self.encode_into(&mut w);
        w.finish()
    }

    /// Encode into a caller-owned (typically reused) buffer. This is the
    /// hot-path entry: transports append frames into a persistent
    /// `Writer` instead of allocating a fresh `Vec` per message.
    pub fn encode_into(&self, w: &mut Writer) {
        match self {
            Message::Pull { worker, keys } => {
                w.u8(T_PULL);
                w.u32(*worker);
                w.u32(keys.len() as u32);
                for k in keys {
                    w.u32(*k);
                }
            }
            Message::PullReply { clock, entries } => {
                w.u8(T_PULL_REPLY);
                w.u64(*clock);
                w.u32(entries.len() as u32);
                for (k, t) in entries {
                    w.u32(*k);
                    w.tensor(t);
                }
            }
            Message::Push { worker, step, entries } => {
                w.u8(T_PUSH);
                w.u32(*worker);
                w.u64(*step);
                w.u32(entries.len() as u32);
                for (k, t) in entries {
                    w.u32(*k);
                    w.tensor(t);
                }
            }
            Message::PushAck { clock } => {
                w.u8(T_PUSH_ACK);
                w.u64(*clock);
            }
            Message::Barrier { worker, step } => {
                w.u8(T_BARRIER);
                w.u32(*worker);
                w.u64(*step);
            }
            Message::BarrierRelease { step } => {
                w.u8(T_BARRIER_RELEASE);
                w.u64(*step);
            }
            Message::Stats => w.u8(T_STATS),
            Message::StatsReply { pulls, pushes, updates } => {
                w.u8(T_STATS_REPLY);
                w.u64(*pulls);
                w.u64(*pushes);
                w.u64(*updates);
            }
            Message::Shutdown => w.u8(T_SHUTDOWN),
            Message::Error { what } => {
                w.u8(T_ERROR);
                w.str(what);
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Message, String> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let msg = match tag {
            T_PULL => {
                let worker = r.u32()?;
                let n = r.u32()? as usize;
                let mut keys = Vec::with_capacity(n);
                for _ in 0..n {
                    keys.push(r.u32()?);
                }
                Message::Pull { worker, keys }
            }
            T_PULL_REPLY => {
                let clock = r.u64()?;
                let n = r.u32()? as usize;
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    let k = r.u32()?;
                    entries.push((k, r.tensor()?));
                }
                Message::PullReply { clock, entries }
            }
            T_PUSH => {
                let worker = r.u32()?;
                let step = r.u64()?;
                let n = r.u32()? as usize;
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    let k = r.u32()?;
                    entries.push((k, r.tensor()?));
                }
                Message::Push { worker, step, entries }
            }
            T_PUSH_ACK => Message::PushAck { clock: r.u64()? },
            T_BARRIER => Message::Barrier { worker: r.u32()?, step: r.u64()? },
            T_BARRIER_RELEASE => Message::BarrierRelease { step: r.u64()? },
            T_STATS => Message::Stats,
            T_STATS_REPLY => Message::StatsReply {
                pulls: r.u64()?,
                pushes: r.u64()?,
                updates: r.u64()?,
            },
            T_SHUTDOWN => Message::Shutdown,
            T_ERROR => Message::Error { what: r.str()? },
            other => return Err(format!("unknown message tag {other}")),
        };
        if r.remaining() != 0 {
            return Err(format!("{} trailing bytes after message", r.remaining()));
        }
        Ok(msg)
    }
}

/// Streaming encoders for the hot-path messages.
///
/// The serve loop and `PsClient` use these to write `PullReply`/`Push`
/// bodies straight from borrowed tensors into a transport's frame
/// buffer — no intermediate `Message` with cloned tensors is ever
/// built. The byte layout is identical to `Message::encode` (asserted
/// by `wire_helpers_match_message_encoding`), so the receive side stays
/// `Message::decode`.
pub mod wire {
    use super::*;

    /// `Pull { worker, keys }` in one pass from a borrowed key slice.
    pub fn pull(w: &mut Writer, worker: u32, keys: &[u32]) {
        w.u8(T_PULL);
        w.u32(worker);
        w.u32(keys.len() as u32);
        for &k in keys {
            w.u32(k);
        }
    }

    /// Header of `PullReply { clock, entries }`; follow with exactly
    /// `n` [`entry`] calls.
    pub fn pull_reply_header(w: &mut Writer, clock: u64, n: u32) {
        w.u8(T_PULL_REPLY);
        w.u64(clock);
        w.u32(n);
    }

    /// Header of `Push { worker, step, entries }`; follow with exactly
    /// `n` [`entry`] calls.
    pub fn push_header(w: &mut Writer, worker: u32, step: u64, n: u32) {
        w.u8(T_PUSH);
        w.u32(worker);
        w.u64(step);
        w.u32(n);
    }

    /// One `(key, tensor)` entry of a `PullReply` or `Push` body,
    /// encoded from a borrowed tensor.
    pub fn entry(w: &mut Writer, key: u32, t: &Tensor) {
        w.u32(key);
        w.tensor(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn roundtrip(m: Message) {
        let buf = m.encode();
        assert_eq!(Message::decode(&buf).unwrap(), m);
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(Message::Pull { worker: 3, keys: vec![0, 5, 9] });
        roundtrip(Message::PullReply {
            clock: 42,
            entries: vec![(1, Tensor::from_vec(&[2], vec![1.0, 2.0]))],
        });
        roundtrip(Message::Push {
            worker: 1,
            step: 7,
            entries: vec![(0, Tensor::scalar(1.5)), (2, Tensor::zeros(&[3, 3]))],
        });
        roundtrip(Message::PushAck { clock: 9 });
        roundtrip(Message::Barrier { worker: 2, step: 11 });
        roundtrip(Message::BarrierRelease { step: 11 });
        roundtrip(Message::Stats);
        roundtrip(Message::StatsReply { pulls: 1, pushes: 2, updates: 3 });
        roundtrip(Message::Shutdown);
        roundtrip(Message::Error { what: "boom".into() });
    }

    #[test]
    fn rejects_unknown_tag() {
        assert!(Message::decode(&[99]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut buf = Message::Stats.encode();
        buf.push(0);
        assert!(Message::decode(&buf).is_err());
    }

    #[test]
    fn wire_helpers_match_message_encoding() {
        let t0 = Tensor::from_vec(&[3], vec![1.0, -2.0, 3.5]);
        let t1 = Tensor::zeros(&[2, 2]);

        let msg = Message::Pull { worker: 7, keys: vec![3, 5, 8] };
        let mut w = Writer::new();
        wire::pull(&mut w, 7, &[3, 5, 8]);
        assert_eq!(w.finish(), msg.encode());

        let msg = Message::Push {
            worker: 2,
            step: 9,
            entries: vec![(4, t0.clone()), (6, t1.clone())],
        };
        let mut w = Writer::new();
        wire::push_header(&mut w, 2, 9, 2);
        wire::entry(&mut w, 4, &t0);
        wire::entry(&mut w, 6, &t1);
        assert_eq!(w.finish(), msg.encode());

        let msg = Message::PullReply { clock: 42, entries: vec![(1, t0.clone())] };
        let mut w = Writer::new();
        wire::pull_reply_header(&mut w, 42, 1);
        wire::entry(&mut w, 1, &t0);
        let buf = w.finish();
        assert_eq!(buf, msg.encode());
        // And the streamed bytes decode to the owned message.
        assert_eq!(Message::decode(&buf).unwrap(), msg);
    }

    #[test]
    fn prop_push_roundtrip() {
        prop::run(40, 0x3355, |g| {
            let n = g.usize(0, 5);
            let entries: Vec<(u32, Tensor)> = (0..n)
                .map(|i| {
                    let len = g.usize(1, 64);
                    (i as u32, Tensor::from_vec(&[len], g.vec_f32(len, -10.0, 10.0)))
                })
                .collect();
            roundtrip(Message::Push { worker: g.u64(0, 100) as u32, step: g.u64(0, 1 << 40), entries });
        });
    }
}
