//! Parameter-server wire protocol (Fig. 1 steps 1 and 7).
//!
//! Workers `Pull` the latest parameter shard values at the start of a
//! mini-batch (step 1, "parameter refresh") and `Push` gradient deltas
//! after compute (step 7, "distributed update"). `Barrier` supports
//! synchronous SGD; `Stats`/`Shutdown` are control-plane.

use super::codec::{Reader, Writer};
use crate::ps::compress::Compressed;
use crate::tensor::Tensor;

/// Epoch stamp meaning "this client does not participate in epoch
/// fencing" (control-plane inspection clients). Servers accept it at any
/// epoch; fenced training clients stamp their routing epoch instead and
/// are rejected on any mismatch.
pub const EPOCH_UNFENCED: u64 = u64::MAX;

/// Protocol messages. `key` identifies a parameter tensor (its index in
/// the artifact manifest); routing to servers is the `ps::router`'s job.
///
/// Worker-originated ops (`Pull`/`Push`/`CompressedPush`/`Barrier`)
/// carry the client's routing `epoch`: a server applies the op only when
/// the stamp matches its own epoch (or is [`EPOCH_UNFENCED`]). A stamp
/// *below* the server's epoch is a stale client; a stamp *above* it is a
/// deposed server that missed its promotion fence — both are rejected
/// with a `stale epoch` error the client treats as a stale route.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker -> server: request current values of `keys`.
    Pull { worker: u32, epoch: u64, keys: Vec<u32> },
    /// Server -> worker: requested values with the server's clock.
    PullReply { clock: u64, entries: Vec<(u32, Tensor)> },
    /// Worker -> server: request quant8-compressed values of `keys` —
    /// the pull-direction twin of [`CompressedPush`](Self::CompressedPush)
    /// that kills Lemma 3.2's dense-broadcast `S_p` term. With `delta`
    /// set the worker asks for bodies encoded as quantized deltas
    /// against the reconstruction it built from the reply stamped
    /// `base` (0 = no base: first pull, or the client discarded its
    /// cache); the server answers with absolute bodies (a forced
    /// resync) whenever it does not hold that exact base for this
    /// worker — first contact, a lost reply, or a promoted replica
    /// whose pull cache started empty.
    CompressedPull { worker: u32, epoch: u64, delta: bool, base: u64, keys: Vec<u32> },
    /// Server -> worker: quant8-compressed parameter values. Each
    /// [`PullEntry`] carries the stored tensor's shape alongside its
    /// quant8 body — workers rebuild full-fidelity tensors from pulls,
    /// and dense pushes derived from them must round-trip the exact
    /// stored shape or the server's shape validation discards them.
    /// Absolute entries overwrite the client's reconstruction, delta
    /// entries accumulate onto it (both sides replay the identical
    /// dequantized f32 adds, so the two reconstructions stay bitwise
    /// equal). `stamp` names this reply in the server's per-worker
    /// delta cache; the client echoes it as `base` on its next delta
    /// pull. Stateless (non-delta) replies carry stamp 0 and touch no
    /// cache, which is what makes them byte-identical across chain
    /// failover.
    CompressedPullReply { clock: u64, stamp: u64, entries: Vec<PullEntry> },
    /// Worker -> server: gradients for `entries` (step `step` at worker).
    /// `seq` is the worker's monotone push counter — replayed frames
    /// (client retries after a fault) carry the same `seq`, so servers
    /// deduplicate them idempotently. The serve loop decodes these
    /// frames with the streaming [`wire::PushBody`], never through this
    /// owned variant.
    Push { worker: u32, step: u64, seq: u64, epoch: u64, entries: Vec<(u32, Tensor)> },
    /// Worker -> server: codec-compressed gradients (§1.1.1's traffic
    /// saver). Each entry is self-describing (sparse or quant8), so no
    /// codec negotiation happens — servers accept any mix per push. The
    /// serve loop decodes these frames with the streaming
    /// [`wire::CompressedPushBody`], never through this owned variant.
    /// `seq` as in [`Push`](Self::Push).
    CompressedPush {
        worker: u32,
        step: u64,
        seq: u64,
        epoch: u64,
        entries: Vec<(u32, Compressed)>,
    },
    /// Server -> worker: push accepted (async mode acks immediately).
    PushAck { clock: u64 },
    /// Worker -> server: enter sync barrier for `step`.
    Barrier { worker: u32, step: u64, epoch: u64 },
    /// Server -> worker: barrier released, proceed to `step`.
    BarrierRelease { step: u64 },
    /// Control: ask the server for counters.
    Stats,
    /// Server -> control: counters.
    StatsReply { pulls: u64, pushes: u64, updates: u64 },
    /// Control: stop serving.
    Shutdown,
    /// Either direction: protocol error.
    Error { what: String },
    /// Primary -> replica (chain replication): one admitted push frame,
    /// forwarded verbatim. `inner` is a complete `Push` or
    /// `CompressedPush` frame body — the replica dispatches it through
    /// the same streaming handlers (building the same per-worker seq
    /// watermarks, so post-failover client replays dedupe identically)
    /// and sends **no reply**; acking is the primary's job.
    ReplForward { inner: Vec<u8> },
    /// Primary -> replica: sync barrier released `step` — apply the
    /// aggregated means for it (the replica holds the same running sums,
    /// fed by forwarded pushes). No reply.
    ReplRelease { step: u64 },
    /// Coordinator -> replica: become the primary for your shard at
    /// routing `epoch` (the old primary's lease expired).
    Promote { epoch: u64 },
    /// Replica -> coordinator: promotion applied; `clock` is the store
    /// clock at takeover (observability).
    PromoteAck { epoch: u64, clock: u64 },
    /// Coordinator -> server: heartbeat probe (lease keep-alive).
    Ping,
    /// Server -> coordinator: heartbeat reply with the server's current
    /// routing epoch and role.
    Pong { epoch: u64, is_primary: bool },
    /// Newcomer -> chain tail: begin the join catch-up. The tail answers
    /// with a [`SnapshotChunk`](Self::SnapshotChunk) stream followed by
    /// [`CatchUpDone`](Self::CatchUpDone), all taken under its
    /// replication cut lock so no concurrent apply can fall between the
    /// snapshot and the chain stream.
    SnapshotRequest,
    /// Tail -> newcomer: one stripe's worth of store state. `velocity`
    /// is present for keys with accumulated momentum — copying it is
    /// what makes the joined store *byte*-identical, not just
    /// parameter-equal.
    SnapshotChunk { entries: Vec<(u32, Tensor, Option<Tensor>)> },
    /// Tail -> newcomer: snapshot complete. Carries everything beyond
    /// the stripes a chain member needs to dedupe and fold exactly like
    /// its peers: store `clock`, routing `epoch`, per-worker async seq
    /// watermarks, the sync released floor, per-step contributed worker
    /// sets, and in-flight sync aggregation sums (`step, key, sum,
    /// count`).
    CatchUpDone {
        clock: u64,
        epoch: u64,
        applied_seq: Vec<(u32, u64)>,
        released_floor: u64,
        contributed: Vec<(u64, Vec<u32>)>,
        agg: Vec<(u64, u32, Tensor, u32)>,
    },
    /// Newcomer -> tail: snapshot installed at `epoch`; attach me as
    /// your downstream chain link (the tail converts this very
    /// connection into the link — frames forwarded after the cut arrive
    /// in order behind the snapshot).
    Join { epoch: u64 },
    /// Replica -> predecessor (chain replication, upstream on the chain
    /// link): cumulative tail-ack watermark. "The first `upto` forwarded
    /// push frames on this connection have been durably applied by every
    /// chain member at or below me." The tail emits one after each
    /// applied forward; mid-chain members relay the count only once
    /// their own downstream has confirmed it — so the primary gates
    /// worker `PushAck`s on end-to-end chain durability without
    /// per-frame round-trips.
    ReplAck { upto: u64 },
    /// Worker -> server: this worker is done (clean shutdown or
    /// coordinator-driven retirement). The server drops any per-worker
    /// soft state — today the delta-pull reconstruction cache — and
    /// replies [`RetireAck`](Self::RetireAck). Purely an optimization:
    /// correctness never depends on the cache, only memory does.
    Retire { worker: u32 },
    /// Server -> worker: retirement processed.
    RetireAck,
    /// Serve client -> any chain member: describe the latest published
    /// parameter snapshot (the read-only serving tier's version
    /// resolution step). Deliberately **not** primary-gated and **not**
    /// epoch-fenced: snapshots are immutable published versions, so a
    /// replica — even a deposed one — answers serve reads directly
    /// instead of bouncing them to the primary.
    SnapshotInfo,
    /// Any chain member -> serve client: the latest published snapshot.
    /// `version` is the store clock at publish time — publishes happen
    /// at deterministic points of the replicated apply stream (sync
    /// step boundaries), so every chain member assigns the same version
    /// numbers to the same bytes. `n_keys` is the snapshot's parameter
    /// count (a whole-model pull streams exactly that many entries).
    /// A server with nothing published answers `Error` instead.
    SnapshotInfoReply { version: u64, clock: u64, n_keys: u32 },
    /// Serve client -> any chain member: stream the parameters of the
    /// **pinned** snapshot `version`. Empty `keys` means every key in
    /// the snapshot. `quant8` selects the reply frame: a dense
    /// [`PullReply`](Self::PullReply) (codec `none`) or a stateless
    /// [`CompressedPullReply`](Self::CompressedPullReply) (codec
    /// `quant8`, stamp 0) — both reply `clock` fields carry the
    /// snapshot's `version`, so the client can verify its pin. A
    /// version that has been retired from the server's bounded
    /// retention window is answered with a `version retired` error the
    /// client treats as "re-resolve and re-pin".
    SnapshotPull { version: u64, quant8: bool, keys: Vec<u32> },
}

/// One entry of a [`CompressedPullReply`](Message::CompressedPullReply):
/// a parameter tensor's shape plus its quant8-encoded values. `delta`
/// marks the body as a quantized delta against the client's cached
/// reconstruction (absolute otherwise). The shape travels on the wire
/// because pulled parameters seed worker-side gradients — a pull that
/// flattened `[6, 6]` to `[36]` would make every dense push from that
/// worker fail the server's shape check.
#[derive(Debug, Clone, PartialEq)]
pub struct PullEntry {
    pub key: u32,
    pub delta: bool,
    pub shape: Vec<usize>,
    pub body: Compressed,
}

const T_PULL: u8 = 1;
const T_PULL_REPLY: u8 = 2;
const T_PUSH: u8 = 3;
const T_PUSH_ACK: u8 = 4;
const T_BARRIER: u8 = 5;
const T_BARRIER_RELEASE: u8 = 6;
const T_STATS: u8 = 7;
const T_STATS_REPLY: u8 = 8;
const T_SHUTDOWN: u8 = 9;
const T_ERROR: u8 = 10;
const T_COMPRESSED_PUSH: u8 = 11;
const T_REPL_FORWARD: u8 = 12;
const T_REPL_RELEASE: u8 = 13;
const T_PROMOTE: u8 = 14;
const T_PROMOTE_ACK: u8 = 15;
const T_PING: u8 = 16;
const T_PONG: u8 = 17;
const T_SNAPSHOT_REQUEST: u8 = 18;
const T_SNAPSHOT_CHUNK: u8 = 19;
const T_CATCH_UP_DONE: u8 = 20;
const T_JOIN: u8 = 21;
const T_COMPRESSED_PULL: u8 = 22;
const T_COMPRESSED_PULL_REPLY: u8 = 23;
const T_REPL_ACK: u8 = 24;
const T_RETIRE: u8 = 25;
const T_RETIRE_ACK: u8 = 26;
const T_SNAPSHOT_INFO: u8 = 27;
const T_SNAPSHOT_INFO_REPLY: u8 = 28;
const T_SNAPSHOT_PULL: u8 = 29;

/// Per-entry codec tags inside a `CompressedPush` body. A
/// `CompressedPull`/`CompressedPullReply` reuses the same byte space for
/// its codec/kind field: `C_QUANT8` marks an absolute quant8 body,
/// `C_QUANT8_DELTA` a quant8 body encoding a delta against the client's
/// reconstruction (pull direction only — pushes never carry deltas).
const C_SPARSE: u8 = 1;
const C_QUANT8: u8 = 2;
const C_QUANT8_DELTA: u8 = 3;
/// Codec byte of a `SnapshotPull` requesting dense (uncompressed)
/// bodies; `C_QUANT8` requests the stateless quant8 encoding.
const C_SERVE_DENSE: u8 = 0;

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(64);
        self.encode_into(&mut w);
        w.finish()
    }

    /// Encode into a caller-owned (typically reused) buffer. This is the
    /// hot-path entry: transports append frames into a persistent
    /// `Writer` instead of allocating a fresh `Vec` per message.
    pub fn encode_into(&self, w: &mut Writer) {
        match self {
            Message::Pull { worker, epoch, keys } => {
                wire::pull(w, *worker, *epoch, keys);
            }
            Message::PullReply { clock, entries } => {
                w.u8(T_PULL_REPLY);
                w.u64(*clock);
                w.u32(entries.len() as u32);
                for (k, t) in entries {
                    w.u32(*k);
                    w.tensor(t);
                }
            }
            Message::CompressedPull { worker, epoch, delta, base, keys } => {
                wire::compressed_pull(w, *worker, *epoch, *delta, *base, keys);
            }
            Message::CompressedPullReply { clock, stamp, entries } => {
                wire::compressed_pull_reply_header(w, *clock, *stamp, entries.len() as u32);
                for e in entries {
                    wire::compressed_pull_entry(w, e.key, e.delta, &e.shape, &e.body);
                }
            }
            Message::Push { worker, step, seq, epoch, entries } => {
                wire::push_header(w, *worker, *step, *seq, *epoch, entries.len() as u32);
                for (k, t) in entries {
                    w.u32(*k);
                    w.tensor(t);
                }
            }
            Message::CompressedPush { worker, step, seq, epoch, entries } => {
                wire::compressed_push_header(
                    w,
                    *worker,
                    *step,
                    *seq,
                    *epoch,
                    entries.len() as u32,
                );
                for (k, c) in entries {
                    wire::compressed_entry(w, *k, c);
                }
            }
            Message::PushAck { clock } => {
                w.u8(T_PUSH_ACK);
                w.u64(*clock);
            }
            Message::Barrier { worker, step, epoch } => {
                w.u8(T_BARRIER);
                w.u32(*worker);
                w.u64(*step);
                w.u64(*epoch);
            }
            Message::BarrierRelease { step } => {
                w.u8(T_BARRIER_RELEASE);
                w.u64(*step);
            }
            Message::Stats => w.u8(T_STATS),
            Message::StatsReply { pulls, pushes, updates } => {
                w.u8(T_STATS_REPLY);
                w.u64(*pulls);
                w.u64(*pushes);
                w.u64(*updates);
            }
            Message::Shutdown => w.u8(T_SHUTDOWN),
            Message::Error { what } => {
                w.u8(T_ERROR);
                w.str(what);
            }
            Message::ReplForward { inner } => {
                wire::repl_forward(w, inner);
            }
            Message::ReplRelease { step } => {
                w.u8(T_REPL_RELEASE);
                w.u64(*step);
            }
            Message::Promote { epoch } => {
                w.u8(T_PROMOTE);
                w.u64(*epoch);
            }
            Message::PromoteAck { epoch, clock } => {
                w.u8(T_PROMOTE_ACK);
                w.u64(*epoch);
                w.u64(*clock);
            }
            Message::Ping => w.u8(T_PING),
            Message::Pong { epoch, is_primary } => {
                w.u8(T_PONG);
                w.u64(*epoch);
                w.u8(*is_primary as u8);
            }
            Message::SnapshotRequest => w.u8(T_SNAPSHOT_REQUEST),
            Message::SnapshotChunk { entries } => {
                w.u8(T_SNAPSHOT_CHUNK);
                w.u32(entries.len() as u32);
                for (k, param, vel) in entries {
                    w.u32(*k);
                    w.tensor(param);
                    match vel {
                        Some(v) => {
                            w.u8(1);
                            w.tensor(v);
                        }
                        None => w.u8(0),
                    }
                }
            }
            Message::CatchUpDone {
                clock,
                epoch,
                applied_seq,
                released_floor,
                contributed,
                agg,
            } => {
                w.u8(T_CATCH_UP_DONE);
                w.u64(*clock);
                w.u64(*epoch);
                w.u32(applied_seq.len() as u32);
                for (worker, seq) in applied_seq {
                    w.u32(*worker);
                    w.u64(*seq);
                }
                w.u64(*released_floor);
                w.u32(contributed.len() as u32);
                for (step, workers) in contributed {
                    w.u64(*step);
                    w.u32(workers.len() as u32);
                    w.u32_raw(workers);
                }
                w.u32(agg.len() as u32);
                for (step, key, sum, count) in agg {
                    w.u64(*step);
                    w.u32(*key);
                    w.tensor(sum);
                    w.u32(*count);
                }
            }
            Message::Join { epoch } => {
                w.u8(T_JOIN);
                w.u64(*epoch);
            }
            Message::ReplAck { upto } => {
                w.u8(T_REPL_ACK);
                w.u64(*upto);
            }
            Message::Retire { worker } => {
                w.u8(T_RETIRE);
                w.u32(*worker);
            }
            Message::RetireAck => w.u8(T_RETIRE_ACK),
            Message::SnapshotInfo => w.u8(T_SNAPSHOT_INFO),
            Message::SnapshotInfoReply { version, clock, n_keys } => {
                w.u8(T_SNAPSHOT_INFO_REPLY);
                w.u64(*version);
                w.u64(*clock);
                w.u32(*n_keys);
            }
            Message::SnapshotPull { version, quant8, keys } => {
                w.u8(T_SNAPSHOT_PULL);
                w.u64(*version);
                w.u8(if *quant8 { C_QUANT8 } else { C_SERVE_DENSE });
                w.u32(keys.len() as u32);
                for &k in keys {
                    w.u32(k);
                }
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Message, String> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let msg = match tag {
            T_PULL => {
                let worker = r.u32()?;
                let epoch = r.u64()?;
                let n = r.u32()? as usize;
                let mut keys = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    keys.push(r.u32()?);
                }
                Message::Pull { worker, epoch, keys }
            }
            T_PULL_REPLY => {
                let clock = r.u64()?;
                let n = r.u32()? as usize;
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    let k = r.u32()?;
                    entries.push((k, r.tensor()?));
                }
                Message::PullReply { clock, entries }
            }
            T_COMPRESSED_PULL => {
                let worker = r.u32()?;
                let epoch = r.u64()?;
                let delta = match r.u8()? {
                    C_QUANT8 => false,
                    C_QUANT8_DELTA => true,
                    other => return Err(format!("unknown pull codec {other}")),
                };
                let base = r.u64()?;
                let n = r.u32()? as usize;
                let mut keys = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    keys.push(r.u32()?);
                }
                Message::CompressedPull { worker, epoch, delta, base, keys }
            }
            T_COMPRESSED_PULL_REPLY => {
                let clock = r.u64()?;
                let stamp = r.u64()?;
                let n = r.u32()? as usize;
                let mut entries = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let key = r.u32()?;
                    let (delta, shape, c) = wire::decode_pull_entry(&mut r)?;
                    entries.push(PullEntry { key, delta, shape, body: c.to_compressed() });
                }
                Message::CompressedPullReply { clock, stamp, entries }
            }
            T_PUSH => {
                let worker = r.u32()?;
                let step = r.u64()?;
                let seq = r.u64()?;
                let epoch = r.u64()?;
                let n = r.u32()? as usize;
                let mut entries = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let k = r.u32()?;
                    entries.push((k, r.tensor()?));
                }
                Message::Push { worker, step, seq, epoch, entries }
            }
            T_COMPRESSED_PUSH => {
                let worker = r.u32()?;
                let step = r.u64()?;
                let seq = r.u64()?;
                let epoch = r.u64()?;
                let n = r.u32()? as usize;
                let mut entries = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let key = r.u32()?;
                    entries.push((key, wire::decode_compressed(&mut r)?.to_compressed()));
                }
                Message::CompressedPush { worker, step, seq, epoch, entries }
            }
            T_PUSH_ACK => Message::PushAck { clock: r.u64()? },
            T_BARRIER => Message::Barrier {
                worker: r.u32()?,
                step: r.u64()?,
                epoch: r.u64()?,
            },
            T_BARRIER_RELEASE => Message::BarrierRelease { step: r.u64()? },
            T_STATS => Message::Stats,
            T_STATS_REPLY => Message::StatsReply {
                pulls: r.u64()?,
                pushes: r.u64()?,
                updates: r.u64()?,
            },
            T_SHUTDOWN => Message::Shutdown,
            T_ERROR => Message::Error { what: r.str()? },
            T_REPL_FORWARD => Message::ReplForward { inner: r.raw(r.remaining())?.to_vec() },
            T_REPL_RELEASE => Message::ReplRelease { step: r.u64()? },
            T_PROMOTE => Message::Promote { epoch: r.u64()? },
            T_PROMOTE_ACK => Message::PromoteAck { epoch: r.u64()?, clock: r.u64()? },
            T_PING => Message::Ping,
            T_PONG => Message::Pong { epoch: r.u64()?, is_primary: r.u8()? != 0 },
            T_SNAPSHOT_REQUEST => Message::SnapshotRequest,
            T_SNAPSHOT_CHUNK => {
                let n = r.u32()? as usize;
                let mut entries = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let k = r.u32()?;
                    let param = r.tensor()?;
                    let vel = if r.u8()? != 0 { Some(r.tensor()?) } else { None };
                    entries.push((k, param, vel));
                }
                Message::SnapshotChunk { entries }
            }
            T_CATCH_UP_DONE => {
                let clock = r.u64()?;
                let epoch = r.u64()?;
                let n = r.u32()? as usize;
                let mut applied_seq = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let worker = r.u32()?;
                    applied_seq.push((worker, r.u64()?));
                }
                let released_floor = r.u64()?;
                let n = r.u32()? as usize;
                let mut contributed = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let step = r.u64()?;
                    let m = r.u32()? as usize;
                    let mut workers = Vec::with_capacity(m.min(1 << 16));
                    for _ in 0..m {
                        workers.push(r.u32()?);
                    }
                    contributed.push((step, workers));
                }
                let n = r.u32()? as usize;
                let mut agg = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let step = r.u64()?;
                    let key = r.u32()?;
                    let sum = r.tensor()?;
                    agg.push((step, key, sum, r.u32()?));
                }
                Message::CatchUpDone {
                    clock,
                    epoch,
                    applied_seq,
                    released_floor,
                    contributed,
                    agg,
                }
            }
            T_JOIN => Message::Join { epoch: r.u64()? },
            T_REPL_ACK => Message::ReplAck { upto: r.u64()? },
            T_RETIRE => Message::Retire { worker: r.u32()? },
            T_RETIRE_ACK => Message::RetireAck,
            T_SNAPSHOT_INFO => Message::SnapshotInfo,
            T_SNAPSHOT_INFO_REPLY => Message::SnapshotInfoReply {
                version: r.u64()?,
                clock: r.u64()?,
                n_keys: r.u32()?,
            },
            T_SNAPSHOT_PULL => {
                let version = r.u64()?;
                let quant8 = match r.u8()? {
                    C_SERVE_DENSE => false,
                    C_QUANT8 => true,
                    other => return Err(format!("unknown serve codec {other}")),
                };
                let n = r.u32()? as usize;
                let mut keys = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    keys.push(r.u32()?);
                }
                Message::SnapshotPull { version, quant8, keys }
            }
            other => return Err(format!("unknown message tag {other}")),
        };
        if r.remaining() != 0 {
            return Err(format!("{} trailing bytes after message", r.remaining()));
        }
        Ok(msg)
    }
}

/// Streaming encoders for the hot-path messages.
///
/// The serve loop and `PsClient` use these to write `PullReply`/`Push`
/// bodies straight from borrowed tensors into a transport's frame
/// buffer — no intermediate `Message` with cloned tensors is ever
/// built. The byte layout is identical to `Message::encode` (asserted
/// by `wire_helpers_match_message_encoding`), so the receive side stays
/// `Message::decode`.
pub mod wire {
    use super::*;
    use crate::ps::compress::{CompressedRef, DenseRef};

    /// `Pull { worker, epoch, keys }` in one pass from a borrowed key
    /// slice.
    pub fn pull(w: &mut Writer, worker: u32, epoch: u64, keys: &[u32]) {
        w.u8(T_PULL);
        w.u32(worker);
        w.u64(epoch);
        w.u32(keys.len() as u32);
        for &k in keys {
            w.u32(k);
        }
    }

    /// Header of `PullReply { clock, entries }`; follow with exactly
    /// `n` [`entry`] calls.
    pub fn pull_reply_header(w: &mut Writer, clock: u64, n: u32) {
        w.u8(T_PULL_REPLY);
        w.u64(clock);
        w.u32(n);
    }

    /// Header of `Push { worker, step, seq, epoch, entries }`; follow
    /// with exactly `n` [`entry`] calls.
    pub fn push_header(w: &mut Writer, worker: u32, step: u64, seq: u64, epoch: u64, n: u32) {
        w.u8(T_PUSH);
        w.u32(worker);
        w.u64(step);
        w.u64(seq);
        w.u64(epoch);
        w.u32(n);
    }

    /// One `(key, tensor)` entry of a `PullReply` or `Push` body,
    /// encoded from a borrowed tensor.
    pub fn entry(w: &mut Writer, key: u32, t: &Tensor) {
        w.u32(key);
        w.tensor(t);
    }

    /// Header of `CompressedPush { worker, step, seq, epoch, entries }`;
    /// follow with exactly `n` [`compressed_entry`] calls.
    pub fn compressed_push_header(
        w: &mut Writer,
        worker: u32,
        step: u64,
        seq: u64,
        epoch: u64,
        n: u32,
    ) {
        w.u8(T_COMPRESSED_PUSH);
        w.u32(worker);
        w.u64(step);
        w.u64(seq);
        w.u64(epoch);
        w.u32(n);
    }

    /// One `(key, compressed)` entry of a `CompressedPush` body, encoded
    /// from a borrowed [`Compressed`]. Layout after the `u32 key` and
    /// `u8 codec` tag:
    /// * sparse (codec 1): `u32 numel, u32 k, k × u32 idx, k × f32 val`
    /// * quant8 (codec 2): `u32 numel, u32 qlen, f32 scale, qlen × i8`
    ///
    /// The byte count after the codec tag is exactly
    /// [`Compressed::wire_bytes`] — the advisor's traffic accounting is
    /// the wire format, not an estimate.
    pub fn compressed_entry(w: &mut Writer, key: u32, c: &Compressed) {
        w.u32(key);
        match c {
            Compressed::Sparse { numel, idx, val } => {
                w.u8(C_SPARSE);
                w.u32(*numel as u32);
                w.u32(idx.len() as u32);
                // Bulk LE copies (same layout as per-element u32/f32).
                w.u32_raw(idx);
                w.f32_raw(val);
            }
            Compressed::Quant8 { numel, scale, q } => {
                w.u8(C_QUANT8);
                w.u32(*numel as u32);
                w.u32(q.len() as u32);
                w.f32(*scale);
                // SAFETY: i8 and u8 have identical size/alignment and
                // every bit pattern is valid — one bulk append.
                let bytes = unsafe {
                    std::slice::from_raw_parts(q.as_ptr().cast::<u8>(), q.len())
                };
                w.raw(bytes);
            }
        }
    }

    /// `CompressedPull { worker, epoch, delta, base, keys }` in one pass
    /// from a borrowed key slice (the client's compressed-pull request).
    pub fn compressed_pull(
        w: &mut Writer,
        worker: u32,
        epoch: u64,
        delta: bool,
        base: u64,
        keys: &[u32],
    ) {
        w.u8(T_COMPRESSED_PULL);
        w.u32(worker);
        w.u64(epoch);
        w.u8(if delta { C_QUANT8_DELTA } else { C_QUANT8 });
        w.u64(base);
        w.u32(keys.len() as u32);
        for &k in keys {
            w.u32(k);
        }
    }

    /// Header of `CompressedPullReply { clock, stamp, entries }`; follow
    /// with exactly `n` [`compressed_pull_entry`] calls.
    pub fn compressed_pull_reply_header(w: &mut Writer, clock: u64, stamp: u64, n: u32) {
        w.u8(T_COMPRESSED_PULL_REPLY);
        w.u64(clock);
        w.u64(stamp);
        w.u32(n);
    }

    /// One [`PullEntry`]-shaped record of a `CompressedPullReply` body,
    /// encoded from a borrowed shape and [`Compressed`]. Layout:
    /// `u32 key, u32 rank, rank × u32 dim`, then the kind byte
    /// (`C_QUANT8` absolute / `C_QUANT8_DELTA` delta) followed by the
    /// same quant8 body as a push entry: `u32 numel, u32 qlen,
    /// f32 scale, qlen × i8`. The byte count after the kind byte is
    /// exactly [`Compressed::wire_bytes`], so one entry is
    /// `9 + 4·rank + wire_bytes` — per-direction traffic accounting
    /// stays the wire format on the pull side too.
    pub fn compressed_pull_entry(
        w: &mut Writer,
        key: u32,
        delta: bool,
        shape: &[usize],
        c: &Compressed,
    ) {
        w.u32(key);
        w.u32(shape.len() as u32);
        for &d in shape {
            w.u32(d as u32);
        }
        match c {
            Compressed::Quant8 { numel, scale, q } => {
                debug_assert_eq!(shape.iter().product::<usize>(), *numel);
                w.u8(if delta { C_QUANT8_DELTA } else { C_QUANT8 });
                w.u32(*numel as u32);
                w.u32(q.len() as u32);
                w.f32(*scale);
                // SAFETY: i8 and u8 have identical size/alignment and
                // every bit pattern is valid — one bulk append.
                let bytes = unsafe {
                    std::slice::from_raw_parts(q.as_ptr().cast::<u8>(), q.len())
                };
                w.raw(bytes);
            }
            // Pull bodies are always quant8. A sparse entry here is a
            // programming error; encode its push layout (codec byte
            // C_SPARSE) so the receiver rejects the frame instead of
            // misreading it.
            Compressed::Sparse { numel, idx, val } => {
                debug_assert!(false, "pull entries are quant8-bodied");
                w.u8(C_SPARSE);
                w.u32(*numel as u32);
                w.u32(idx.len() as u32);
                w.u32_raw(idx);
                w.f32_raw(val);
            }
        }
    }

    /// True when `frame` is a `CompressedPullReply` body — the client
    /// routes such frames into [`CompressedPullReplyBody`] instead of
    /// `Message::decode`.
    pub fn is_compressed_pull_reply(frame: &[u8]) -> bool {
        frame.first() == Some(&T_COMPRESSED_PULL_REPLY)
    }

    /// True when `frame` is a `CompressedPush` body — the serve loop
    /// routes such frames into [`CompressedPushBody`] instead of
    /// `Message::decode`.
    pub fn is_compressed_push(frame: &[u8]) -> bool {
        frame.first() == Some(&T_COMPRESSED_PUSH)
    }

    /// True when `frame` is a dense `Push` body — the serve loop routes
    /// such frames into [`PushBody`] instead of `Message::decode`.
    pub fn is_push(frame: &[u8]) -> bool {
        frame.first() == Some(&T_PUSH)
    }

    /// `ReplForward { inner }` in one pass from the borrowed frame the
    /// primary just admitted — chain replication's zero-copy forward
    /// (one tag byte of framing overhead, no re-encode of the body).
    pub fn repl_forward(w: &mut Writer, inner: &[u8]) {
        w.u8(T_REPL_FORWARD);
        w.raw(inner);
    }

    /// True when `frame` is a replication forward — the serve loop
    /// routes such frames into the push handlers with no reply.
    pub fn is_repl_forward(frame: &[u8]) -> bool {
        frame.first() == Some(&T_REPL_FORWARD)
    }

    /// The forwarded inner frame of a `ReplForward`, borrowed.
    pub fn repl_forward_inner(frame: &[u8]) -> &[u8] {
        debug_assert!(is_repl_forward(frame));
        &frame[1..]
    }

    /// One `SnapshotChunk` frame encoded straight from borrowed store
    /// entries (the join catch-up's per-stripe stream — no tensor is
    /// cloned to send it). Wire layout matches the owned
    /// [`Message::SnapshotChunk`] decode exactly.
    pub fn snapshot_chunk(w: &mut Writer, entries: &[(u32, &Tensor, Option<&Tensor>)]) {
        w.u8(T_SNAPSHOT_CHUNK);
        w.u32(entries.len() as u32);
        for &(k, param, vel) in entries {
            w.u32(k);
            w.tensor(param);
            match vel {
                Some(v) => {
                    w.u8(1);
                    w.tensor(v);
                }
                None => w.u8(0),
            }
        }
    }

    /// Streaming dense-`Push` decoder: yields `(key, DenseRef)` entries
    /// whose f32 payloads stay borrowed wire bytes — the dense twin of
    /// [`CompressedPushBody`], so the server applies pushed gradients
    /// without materializing an owned `Tensor` per entry.
    pub struct PushBody<'a> {
        pub worker: u32,
        pub step: u64,
        pub seq: u64,
        pub epoch: u64,
        remaining: usize,
        r: Reader<'a>,
    }

    impl<'a> PushBody<'a> {
        pub fn decode(frame: &'a [u8]) -> Result<Self, String> {
            let mut r = Reader::new(frame);
            let tag = r.u8()?;
            if tag != T_PUSH {
                return Err(format!("not a Push frame (tag {tag})"));
            }
            let worker = r.u32()?;
            let step = r.u64()?;
            let seq = r.u64()?;
            let epoch = r.u64()?;
            let remaining = r.u32()? as usize;
            Ok(PushBody { worker, step, seq, epoch, remaining, r })
        }

        /// Entries not yet yielded.
        pub fn remaining(&self) -> usize {
            self.remaining
        }

        /// Next `(key, view)` entry; `None` once every entry (and the
        /// whole frame) is consumed. Trailing bytes after the last entry
        /// are an error, matching `Message::decode` strictness.
        pub fn next_entry(&mut self) -> Option<Result<(u32, DenseRef<'a>), String>> {
            if self.remaining == 0 {
                if self.r.remaining() != 0 {
                    return Some(Err(format!(
                        "{} trailing bytes after Push",
                        self.r.remaining()
                    )));
                }
                return None;
            }
            self.remaining -= 1;
            Some(self.entry())
        }

        fn entry(&mut self) -> Result<(u32, DenseRef<'a>), String> {
            let key = self.r.u32()?;
            // Tensor wire layout: u32 rank, rank x u32 dim, u32 numel,
            // numel x f32 — the payload is borrowed, not copied.
            let rank = self.r.u32()? as usize;
            if rank > 16 {
                return Err(format!("implausible tensor rank {rank}"));
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(self.r.u32()? as usize);
            }
            let numel = self.r.u32()? as usize;
            if shape.iter().product::<usize>() != numel {
                return Err(format!(
                    "tensor shape {shape:?} disagrees with payload {numel}"
                ));
            }
            let data = self.r.raw(numel * 4)?;
            let view = DenseRef::new(shape, data)?;
            Ok((key, view))
        }
    }

    /// Streaming `CompressedPush` decoder: yields `(key, CompressedRef)`
    /// entries borrowed straight from the received frame. No owned
    /// `Tensor` (or even `Vec`) is materialized per entry — the server
    /// scatters each view directly into its store.
    pub struct CompressedPushBody<'a> {
        pub worker: u32,
        pub step: u64,
        pub seq: u64,
        pub epoch: u64,
        remaining: usize,
        r: Reader<'a>,
    }

    impl<'a> CompressedPushBody<'a> {
        pub fn decode(frame: &'a [u8]) -> Result<Self, String> {
            let mut r = Reader::new(frame);
            let tag = r.u8()?;
            if tag != T_COMPRESSED_PUSH {
                return Err(format!("not a CompressedPush frame (tag {tag})"));
            }
            let worker = r.u32()?;
            let step = r.u64()?;
            let seq = r.u64()?;
            let epoch = r.u64()?;
            let remaining = r.u32()? as usize;
            Ok(CompressedPushBody { worker, step, seq, epoch, remaining, r })
        }

        /// Entries not yet yielded.
        pub fn remaining(&self) -> usize {
            self.remaining
        }

        /// Next `(key, view)` entry; `None` once every entry (and the
        /// whole frame) is consumed. Trailing bytes after the last entry
        /// are an error, matching `Message::decode` strictness.
        pub fn next_entry(&mut self) -> Option<Result<(u32, CompressedRef<'a>), String>> {
            if self.remaining == 0 {
                if self.r.remaining() != 0 {
                    return Some(Err(format!(
                        "{} trailing bytes after CompressedPush",
                        self.r.remaining()
                    )));
                }
                return None;
            }
            self.remaining -= 1;
            Some(self.entry())
        }

        fn entry(&mut self) -> Result<(u32, CompressedRef<'a>), String> {
            let key = self.r.u32()?;
            let c = decode_compressed(&mut self.r)?;
            Ok((key, c))
        }
    }

    /// One streamed `CompressedPullReply` entry: the [`PullEntry`] twin
    /// whose quant8 payload stays borrowed wire bytes.
    pub struct PullEntryRef<'a> {
        pub key: u32,
        pub delta: bool,
        pub shape: Vec<usize>,
        pub body: CompressedRef<'a>,
    }

    /// Streaming `CompressedPullReply` decoder: yields [`PullEntryRef`]
    /// entries whose quant8 payloads are borrowed straight from the
    /// received frame — the pull-direction twin of
    /// [`CompressedPushBody`]. The client dequantizes each view directly
    /// into its output buffer; no owned `Compressed` is built per entry.
    pub struct CompressedPullReplyBody<'a> {
        pub clock: u64,
        pub stamp: u64,
        remaining: usize,
        r: Reader<'a>,
    }

    impl<'a> CompressedPullReplyBody<'a> {
        pub fn decode(frame: &'a [u8]) -> Result<Self, String> {
            let mut r = Reader::new(frame);
            let tag = r.u8()?;
            if tag != T_COMPRESSED_PULL_REPLY {
                return Err(format!("not a CompressedPullReply frame (tag {tag})"));
            }
            let clock = r.u64()?;
            let stamp = r.u64()?;
            let remaining = r.u32()? as usize;
            Ok(CompressedPullReplyBody { clock, stamp, remaining, r })
        }

        /// Entries not yet yielded.
        pub fn remaining(&self) -> usize {
            self.remaining
        }

        /// Next [`PullEntryRef`]; `None` once every entry (and the
        /// whole frame) is consumed. Trailing bytes after the last
        /// entry are an error, matching `Message::decode` strictness.
        pub fn next_entry(&mut self) -> Option<Result<PullEntryRef<'a>, String>> {
            if self.remaining == 0 {
                if self.r.remaining() != 0 {
                    return Some(Err(format!(
                        "{} trailing bytes after CompressedPullReply",
                        self.r.remaining()
                    )));
                }
                return None;
            }
            self.remaining -= 1;
            Some(self.entry())
        }

        fn entry(&mut self) -> Result<PullEntryRef<'a>, String> {
            let key = self.r.u32()?;
            let (delta, shape, body) = decode_pull_entry(&mut self.r)?;
            Ok(PullEntryRef { key, delta, shape, body })
        }
    }

    /// Decode one pull-entry body (shape then kind-tagged quant8
    /// payload) as a borrowed view, validating that the declared shape
    /// and payload agree. Accepts only quant8 bodies (absolute or
    /// delta) — the pull direction never carries sparse payloads.
    pub(super) fn decode_pull_entry<'a>(
        r: &mut Reader<'a>,
    ) -> Result<(bool, Vec<usize>, CompressedRef<'a>), String> {
        let rank = r.u32()? as usize;
        if rank > 16 {
            return Err(format!("implausible tensor rank {rank}"));
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u32()? as usize);
        }
        let kind = r.u8()?;
        let delta = match kind {
            C_QUANT8 => false,
            C_QUANT8_DELTA => true,
            other => return Err(format!("unknown pull entry kind {other}")),
        };
        let numel = r.u32()? as usize;
        if shape.iter().product::<usize>() != numel {
            return Err(format!(
                "pull entry shape {shape:?} disagrees with payload {numel}"
            ));
        }
        let qlen = r.u32()? as usize;
        if qlen != numel {
            return Err(format!("quant8 payload {qlen} != numel {numel}"));
        }
        let scale = r.f32()?;
        let q = r.raw(qlen)?;
        Ok((delta, shape, CompressedRef::Quant8 { numel, scale, q }))
    }

    /// Decode one codec-tagged compressed payload as a borrowed view.
    pub(super) fn decode_compressed<'a>(r: &mut Reader<'a>) -> Result<CompressedRef<'a>, String> {
        let codec = r.u8()?;
        match codec {
            C_SPARSE => {
                let numel = r.u32()? as usize;
                let k = r.u32()? as usize;
                if k > numel {
                    return Err(format!("sparse k {k} exceeds numel {numel}"));
                }
                let idx = r.raw(k * 4)?;
                let val = r.raw(k * 4)?;
                Ok(CompressedRef::Sparse { numel, idx, val })
            }
            C_QUANT8 => {
                let numel = r.u32()? as usize;
                let qlen = r.u32()? as usize;
                if qlen != numel {
                    return Err(format!("quant8 payload {qlen} != numel {numel}"));
                }
                let scale = r.f32()?;
                let q = r.raw(qlen)?;
                Ok(CompressedRef::Quant8 { numel, scale, q })
            }
            other => Err(format!("unknown compression codec {other}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn roundtrip(m: Message) {
        let buf = m.encode();
        assert_eq!(Message::decode(&buf).unwrap(), m);
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(Message::Pull { worker: 3, epoch: 2, keys: vec![0, 5, 9] });
        roundtrip(Message::Pull { worker: 3, epoch: EPOCH_UNFENCED, keys: vec![] });
        roundtrip(Message::PullReply {
            clock: 42,
            entries: vec![(1, Tensor::from_vec(&[2], vec![1.0, 2.0]))],
        });
        roundtrip(Message::Push {
            worker: 1,
            step: 7,
            seq: 42,
            epoch: 1,
            entries: vec![(0, Tensor::scalar(1.5)), (2, Tensor::zeros(&[3, 3]))],
        });
        roundtrip(Message::PushAck { clock: 9 });
        roundtrip(Message::Barrier { worker: 2, step: 11, epoch: 4 });
        roundtrip(Message::BarrierRelease { step: 11 });
        roundtrip(Message::Stats);
        roundtrip(Message::StatsReply { pulls: 1, pushes: 2, updates: 3 });
        roundtrip(Message::Shutdown);
        roundtrip(Message::Error { what: "boom".into() });
        roundtrip(Message::ReplRelease { step: 17 });
        roundtrip(Message::Promote { epoch: 3 });
        roundtrip(Message::PromoteAck { epoch: 3, clock: 99 });
        roundtrip(Message::Ping);
        roundtrip(Message::Pong { epoch: 2, is_primary: true });
        roundtrip(Message::Pong { epoch: 0, is_primary: false });
        roundtrip(Message::ReplAck { upto: 12 });
        roundtrip(Message::Retire { worker: 5 });
        roundtrip(Message::RetireAck);
    }

    #[test]
    fn serve_snapshot_variants_roundtrip() {
        roundtrip(Message::SnapshotInfo);
        roundtrip(Message::SnapshotInfoReply { version: 42, clock: 42, n_keys: 7 });
        roundtrip(Message::SnapshotInfoReply { version: 0, clock: 0, n_keys: 0 });
        roundtrip(Message::SnapshotPull { version: 42, quant8: false, keys: vec![0, 3, 9] });
        roundtrip(Message::SnapshotPull { version: 1, quant8: true, keys: vec![] });
    }

    #[test]
    fn serve_snapshot_pull_rejects_malformed() {
        // Unknown codec byte in the request.
        let mut buf = Message::SnapshotPull { version: 5, quant8: true, keys: vec![1] }.encode();
        buf[9] = 99; // the codec byte sits right after tag + u64 version
        assert!(Message::decode(&buf).is_err());
        // Trailing bytes after the key list.
        let mut buf = Message::SnapshotPull { version: 5, quant8: false, keys: vec![1] }.encode();
        buf.push(0);
        assert!(Message::decode(&buf).is_err());
        // Truncated info reply.
        let buf = Message::SnapshotInfoReply { version: 1, clock: 2, n_keys: 3 }.encode();
        assert!(Message::decode(&buf[..buf.len() - 1]).is_err());
    }

    #[test]
    fn streamed_snapshot_chunk_matches_owned_encoding() {
        let p0 = Tensor::from_vec(&[2], vec![1.0, -2.0]);
        let p1 = Tensor::zeros(&[2, 2]);
        let v1 = Tensor::from_vec(&[2, 2], vec![0.5, 0.0, -0.5, 1.0]);
        let owned = Message::SnapshotChunk {
            entries: vec![(0, p0.clone(), None), (7, p1.clone(), Some(v1.clone()))],
        };
        let mut w = Writer::new();
        wire::snapshot_chunk(&mut w, &[(0, &p0, None), (7, &p1, Some(&v1))]);
        let buf = w.finish();
        assert_eq!(buf, owned.encode());
        assert_eq!(Message::decode(&buf).unwrap(), owned);
    }

    #[test]
    fn catch_up_variants_roundtrip() {
        roundtrip(Message::SnapshotRequest);
        roundtrip(Message::SnapshotChunk { entries: vec![] });
        roundtrip(Message::SnapshotChunk {
            entries: vec![
                (0, Tensor::from_vec(&[2], vec![1.0, -2.0]), None),
                (
                    7,
                    Tensor::zeros(&[2, 2]),
                    Some(Tensor::from_vec(&[2, 2], vec![0.5, 0.0, -0.5, 1.0])),
                ),
            ],
        });
        roundtrip(Message::CatchUpDone {
            clock: 99,
            epoch: 3,
            applied_seq: vec![(0, 41), (2, 7)],
            released_floor: 11,
            contributed: vec![(11, vec![0, 2]), (12, vec![1])],
            agg: vec![(12, 4, Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]), 2)],
        });
        roundtrip(Message::CatchUpDone {
            clock: 0,
            epoch: 0,
            applied_seq: vec![],
            released_floor: 0,
            contributed: vec![],
            agg: vec![],
        });
        roundtrip(Message::Join { epoch: 5 });
    }

    #[test]
    fn repl_forward_wraps_frame_verbatim() {
        // The forward's inner bytes are the admitted frame, byte for
        // byte — the replica's streaming handlers decode them directly.
        let push = Message::Push {
            worker: 2,
            step: 4,
            seq: 7,
            epoch: 0,
            entries: vec![(0, Tensor::from_vec(&[2], vec![1.0, -2.0]))],
        };
        let inner = push.encode();
        let fwd = Message::ReplForward { inner: inner.clone() };
        let buf = fwd.encode();
        assert!(wire::is_repl_forward(&buf));
        assert!(!wire::is_repl_forward(&inner));
        assert_eq!(wire::repl_forward_inner(&buf), &inner[..]);
        assert_eq!(Message::decode(&buf).unwrap(), fwd);
        // The streamed helper produces identical bytes.
        let mut w = Writer::new();
        wire::repl_forward(&mut w, &inner);
        assert_eq!(w.finish(), buf);
        // And the inner frame round-trips through the push decoder.
        assert_eq!(Message::decode(wire::repl_forward_inner(&buf)).unwrap(), push);
    }

    #[test]
    fn rejects_unknown_tag() {
        assert!(Message::decode(&[99]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut buf = Message::Stats.encode();
        buf.push(0);
        assert!(Message::decode(&buf).is_err());
    }

    #[test]
    fn wire_helpers_match_message_encoding() {
        let t0 = Tensor::from_vec(&[3], vec![1.0, -2.0, 3.5]);
        let t1 = Tensor::zeros(&[2, 2]);

        let msg = Message::Pull { worker: 7, epoch: 3, keys: vec![3, 5, 8] };
        let mut w = Writer::new();
        wire::pull(&mut w, 7, 3, &[3, 5, 8]);
        assert_eq!(w.finish(), msg.encode());

        let msg = Message::Push {
            worker: 2,
            step: 9,
            seq: 5,
            epoch: 1,
            entries: vec![(4, t0.clone()), (6, t1.clone())],
        };
        let mut w = Writer::new();
        wire::push_header(&mut w, 2, 9, 5, 1, 2);
        wire::entry(&mut w, 4, &t0);
        wire::entry(&mut w, 6, &t1);
        assert_eq!(w.finish(), msg.encode());

        let msg = Message::PullReply { clock: 42, entries: vec![(1, t0.clone())] };
        let mut w = Writer::new();
        wire::pull_reply_header(&mut w, 42, 1);
        wire::entry(&mut w, 1, &t0);
        let buf = w.finish();
        assert_eq!(buf, msg.encode());
        // And the streamed bytes decode to the owned message.
        assert_eq!(Message::decode(&buf).unwrap(), msg);
    }

    fn sample_compressed() -> (Compressed, Compressed) {
        (
            Compressed::Sparse { numel: 6, idx: vec![1, 4], val: vec![2.5, -1.0] },
            Compressed::Quant8 { numel: 3, scale: 0.5, q: vec![-7, 0, 127] },
        )
    }

    #[test]
    fn compressed_push_roundtrip() {
        let (c1, c2) = sample_compressed();
        roundtrip(Message::CompressedPush {
            worker: 4,
            step: 9,
            seq: 3,
            epoch: 2,
            entries: vec![(0, c1), (3, c2)],
        });
        roundtrip(Message::CompressedPush {
            worker: 0,
            step: 0,
            seq: 0,
            epoch: 0,
            entries: vec![],
        });
    }

    #[test]
    fn compressed_wire_helpers_match_message_encoding() {
        let (c1, c2) = sample_compressed();
        let msg = Message::CompressedPush {
            worker: 2,
            step: 11,
            seq: 6,
            epoch: 4,
            entries: vec![(5, c1.clone()), (7, c2.clone())],
        };
        let mut w = Writer::new();
        wire::compressed_push_header(&mut w, 2, 11, 6, 4, 2);
        wire::compressed_entry(&mut w, 5, &c1);
        wire::compressed_entry(&mut w, 7, &c2);
        let buf = w.finish();
        assert_eq!(buf, msg.encode());
        assert_eq!(Message::decode(&buf).unwrap(), msg);
    }

    #[test]
    fn compressed_entry_bytes_match_wire_accounting() {
        // Frame body = 33-byte header (tag, worker, step, seq, epoch, n)
        // + per entry (5 + wire_bytes): the advisor's S_p accounting IS
        // the byte count on the wire.
        let (c1, c2) = sample_compressed();
        for c in [&c1, &c2] {
            let mut w = Writer::new();
            wire::compressed_entry(&mut w, 9, c);
            assert_eq!(w.len(), 4 + 1 + c.wire_bytes());
        }
        let msg = Message::CompressedPush {
            worker: 1,
            step: 2,
            seq: 0,
            epoch: 0,
            entries: vec![(0, c1.clone()), (1, c2.clone())],
        };
        assert_eq!(
            msg.encode().len(),
            33 + (5 + c1.wire_bytes()) + (5 + c2.wire_bytes())
        );
    }

    #[test]
    fn push_stream_decode_matches_owned() {
        // The streaming dense decoder yields exactly the owned message's
        // entries, with payloads borrowed from the frame.
        let t0 = Tensor::from_vec(&[3], vec![1.0, -2.0, 3.5]);
        let t1 = Tensor::from_vec(&[2, 2], vec![0.5, 0.0, -0.5, 8.0]);
        let msg = Message::Push {
            worker: 7,
            step: 13,
            seq: 21,
            epoch: 6,
            entries: vec![(1, t0.clone()), (4, t1.clone())],
        };
        let buf = msg.encode();
        assert!(wire::is_push(&buf));
        assert!(!wire::is_push(&Message::Stats.encode()));

        let mut body = wire::PushBody::decode(&buf).unwrap();
        assert_eq!(
            (body.worker, body.step, body.seq, body.epoch, body.remaining()),
            (7, 13, 21, 6, 2)
        );
        let mut got = Vec::new();
        while let Some(e) = body.next_entry() {
            let (k, view) = e.unwrap();
            got.push((k, view.to_tensor()));
        }
        assert_eq!(got, vec![(1, t0), (4, t1)]);
    }

    #[test]
    fn push_stream_decode_rejects_malformed() {
        let msg = Message::Push {
            worker: 0,
            step: 0,
            seq: 0,
            epoch: 0,
            entries: vec![(0, Tensor::from_vec(&[2], vec![1.0, 2.0]))],
        };
        // Trailing garbage after the last entry.
        let mut buf = msg.encode();
        buf.push(0);
        let mut body = wire::PushBody::decode(&buf).unwrap();
        assert!(body.next_entry().unwrap().is_ok());
        assert!(body.next_entry().unwrap().is_err());
        // Not a push frame at all; truncated header; truncated entry.
        assert!(wire::PushBody::decode(&Message::Stats.encode()).is_err());
        assert!(wire::PushBody::decode(&msg.encode()[..10]).is_err());
        let whole = msg.encode();
        let mut body = wire::PushBody::decode(&whole[..whole.len() - 1]).unwrap();
        assert!(body.next_entry().unwrap().is_err());
        // Shape/numel disagreement rejected.
        let mut w = Writer::new();
        wire::push_header(&mut w, 0, 0, 0, 0, 1);
        w.u32(0); // key
        w.u32(1); // rank
        w.u32(3); // shape [3]
        w.u32(2); // numel 2 != 3
        w.f32(1.0);
        w.f32(2.0);
        let bad = w.finish();
        let mut body = wire::PushBody::decode(&bad).unwrap();
        assert!(body.next_entry().unwrap().is_err());
    }

    #[test]
    fn compressed_push_stream_decode_matches_owned() {
        let (c1, c2) = sample_compressed();
        let msg = Message::CompressedPush {
            worker: 4,
            step: 9,
            seq: 17,
            epoch: 2,
            entries: vec![(0, c1.clone()), (3, c2.clone())],
        };
        let buf = msg.encode();
        assert!(wire::is_compressed_push(&buf));
        assert!(!wire::is_compressed_push(&Message::Stats.encode()));

        let mut body = wire::CompressedPushBody::decode(&buf).unwrap();
        assert_eq!(
            (body.worker, body.step, body.seq, body.epoch, body.remaining()),
            (4, 9, 17, 2, 2)
        );
        let mut got = Vec::new();
        while let Some(e) = body.next_entry() {
            let (k, view) = e.unwrap();
            got.push((k, view.to_compressed()));
        }
        assert_eq!(got, vec![(0, c1), (3, c2)]);
    }

    #[test]
    fn compressed_push_stream_decode_rejects_malformed() {
        let (c1, _) = sample_compressed();
        let msg = Message::CompressedPush {
            worker: 0,
            step: 0,
            seq: 0,
            epoch: 0,
            entries: vec![(0, c1)],
        };
        let mut buf = msg.encode();
        // Trailing garbage after the last entry.
        buf.push(0);
        let mut body = wire::CompressedPushBody::decode(&buf).unwrap();
        assert!(body.next_entry().unwrap().is_ok());
        assert!(body.next_entry().unwrap().is_err());
        // Not a compressed-push frame at all.
        assert!(wire::CompressedPushBody::decode(&Message::Stats.encode()).is_err());
        // Truncated header.
        assert!(wire::CompressedPushBody::decode(&msg.encode()[..10]).is_err());
        // Truncated entry: drop the last byte of a valid frame.
        let whole = msg.encode();
        let mut body = wire::CompressedPushBody::decode(&whole[..whole.len() - 1]).unwrap();
        assert!(body.next_entry().unwrap().is_err());
        // Sparse k > numel rejected by the owned decoder too.
        let mut w = Writer::new();
        wire::compressed_push_header(&mut w, 0, 0, 0, 0, 1);
        w.u32(0); // key
        w.u8(1); // C_SPARSE
        w.u32(2); // numel
        w.u32(3); // k > numel
        let bad = w.finish();
        assert!(Message::decode(&bad).is_err());
    }

    fn sample_pull_entries() -> (PullEntry, PullEntry) {
        (
            PullEntry {
                key: 0,
                delta: false,
                shape: vec![3],
                body: Compressed::Quant8 { numel: 3, scale: 0.5, q: vec![-7, 0, 127] },
            },
            PullEntry {
                key: 3,
                delta: true,
                shape: vec![2, 2],
                body: Compressed::Quant8 { numel: 4, scale: 0.25, q: vec![1, -1, 64, -127] },
            },
        )
    }

    #[test]
    fn compressed_pull_roundtrip() {
        roundtrip(Message::CompressedPull {
            worker: 3,
            epoch: 2,
            delta: false,
            base: 0,
            keys: vec![0, 5, 9],
        });
        roundtrip(Message::CompressedPull {
            worker: 0,
            epoch: EPOCH_UNFENCED,
            delta: true,
            base: 17,
            keys: vec![],
        });
        let (e1, e2) = sample_pull_entries();
        roundtrip(Message::CompressedPullReply {
            clock: 42,
            stamp: 7,
            entries: vec![e1, e2],
        });
        roundtrip(Message::CompressedPullReply { clock: 0, stamp: 0, entries: vec![] });
    }

    #[test]
    fn compressed_pull_wire_helpers_match_message_encoding() {
        let msg = Message::CompressedPull {
            worker: 7,
            epoch: 3,
            delta: true,
            base: 11,
            keys: vec![3, 5, 8],
        };
        let mut w = Writer::new();
        wire::compressed_pull(&mut w, 7, 3, true, 11, &[3, 5, 8]);
        assert_eq!(w.finish(), msg.encode());

        let (mut e1, mut e2) = sample_pull_entries();
        e1.key = 1;
        e2.key = 4;
        let msg = Message::CompressedPullReply {
            clock: 42,
            stamp: 9,
            entries: vec![e1.clone(), e2.clone()],
        };
        let mut w = Writer::new();
        wire::compressed_pull_reply_header(&mut w, 42, 9, 2);
        wire::compressed_pull_entry(&mut w, e1.key, e1.delta, &e1.shape, &e1.body);
        wire::compressed_pull_entry(&mut w, e2.key, e2.delta, &e2.shape, &e2.body);
        let buf = w.finish();
        assert_eq!(buf, msg.encode());
        assert_eq!(Message::decode(&buf).unwrap(), msg);
    }

    #[test]
    fn pull_bytes_match_wire_accounting() {
        // Compressed reply = 21-byte header (tag, clock, stamp, n) +
        // per entry (9 + 4·rank + wire_bytes: key, rank, dims, kind,
        // quant8 body); the request adds one codec byte and a u64 base
        // over a dense Pull. These formulas ARE the client's
        // pull_wire_bytes accounting.
        let (e1, e2) = sample_pull_entries();
        for e in [&e1, &e2] {
            let mut w = Writer::new();
            wire::compressed_pull_entry(&mut w, 9, e.delta, &e.shape, &e.body);
            assert_eq!(w.len(), 9 + 4 * e.shape.len() + e.body.wire_bytes());
        }
        let msg = Message::CompressedPullReply {
            clock: 1,
            stamp: 2,
            entries: vec![e1.clone(), e2.clone()],
        };
        assert_eq!(
            msg.encode().len(),
            21 + (9 + 4 + e1.body.wire_bytes()) + (9 + 8 + e2.body.wire_bytes())
        );
        let req = Message::CompressedPull {
            worker: 0,
            epoch: 0,
            delta: false,
            base: 0,
            keys: vec![1, 2, 3],
        };
        assert_eq!(req.encode().len(), 26 + 4 * 3);

        // Dense reply = 13-byte header + per entry
        // (4 key + 8 + 4·rank + 4·numel) — pinned here because the
        // client reports dense pull traffic from this formula.
        let t0 = Tensor::from_vec(&[3], vec![1.0, -2.0, 3.5]);
        let t1 = Tensor::zeros(&[2, 2]);
        let msg = Message::PullReply { clock: 5, entries: vec![(0, t0), (1, t1)] };
        assert_eq!(msg.encode().len(), 13 + (12 + 4 + 4 * 3) + (12 + 8 + 4 * 4));
    }

    #[test]
    fn compressed_pull_reply_stream_decode_matches_owned() {
        let (e1, e2) = sample_pull_entries();
        let msg = Message::CompressedPullReply {
            clock: 42,
            stamp: 17,
            entries: vec![e1.clone(), e2.clone()],
        };
        let buf = msg.encode();
        assert!(wire::is_compressed_pull_reply(&buf));
        assert!(!wire::is_compressed_pull_reply(&Message::Stats.encode()));

        let mut body = wire::CompressedPullReplyBody::decode(&buf).unwrap();
        assert_eq!((body.clock, body.stamp, body.remaining()), (42, 17, 2));
        let mut got = Vec::new();
        while let Some(e) = body.next_entry() {
            let e = e.unwrap();
            got.push(PullEntry {
                key: e.key,
                delta: e.delta,
                shape: e.shape,
                body: e.body.to_compressed(),
            });
        }
        assert_eq!(got, vec![e1, e2]);
    }

    #[test]
    fn compressed_pull_reply_stream_decode_rejects_malformed() {
        let (e1, _) = sample_pull_entries();
        let msg = Message::CompressedPullReply {
            clock: 0,
            stamp: 0,
            entries: vec![e1],
        };
        // Trailing garbage after the last entry.
        let mut buf = msg.encode();
        buf.push(0);
        let mut body = wire::CompressedPullReplyBody::decode(&buf).unwrap();
        assert!(body.next_entry().unwrap().is_ok());
        assert!(body.next_entry().unwrap().is_err());
        // Not a compressed-pull-reply frame at all; truncated header;
        // truncated entry.
        assert!(wire::CompressedPullReplyBody::decode(&Message::Stats.encode()).is_err());
        assert!(wire::CompressedPullReplyBody::decode(&msg.encode()[..10]).is_err());
        let whole = msg.encode();
        let mut body = wire::CompressedPullReplyBody::decode(&whole[..whole.len() - 1]).unwrap();
        assert!(body.next_entry().unwrap().is_err());
        // A sparse-tagged entry body is rejected: pulls are quant8-only.
        let mut w = Writer::new();
        wire::compressed_pull_reply_header(&mut w, 0, 0, 1);
        w.u32(0); // key
        w.u32(1); // rank
        w.u32(2); // dim
        w.u8(1); // C_SPARSE
        w.u32(2);
        w.u32(1);
        let bad = w.finish();
        let mut body = wire::CompressedPullReplyBody::decode(&bad).unwrap();
        assert!(body.next_entry().unwrap().is_err());
        assert!(Message::decode(&bad).is_err());
        // qlen != numel rejected.
        let mut w = Writer::new();
        wire::compressed_pull_reply_header(&mut w, 0, 0, 1);
        w.u32(0); // key
        w.u32(1); // rank
        w.u32(3); // dim
        w.u8(2); // C_QUANT8
        w.u32(3); // numel
        w.u32(2); // qlen != numel
        w.f32(1.0);
        w.raw(&[0, 0]);
        let bad = w.finish();
        let mut body = wire::CompressedPullReplyBody::decode(&bad).unwrap();
        assert!(body.next_entry().unwrap().is_err());
        // Shape that disagrees with the payload rejected — a flattened
        // or corrupted shape must never reach the client's tensor
        // rebuild.
        let mut w = Writer::new();
        wire::compressed_pull_reply_header(&mut w, 0, 0, 1);
        w.u32(0); // key
        w.u32(2); // rank
        w.u32(2); // dims [2, 3]: product 6
        w.u32(3);
        w.u8(2); // C_QUANT8
        w.u32(4); // numel != 6
        w.u32(4);
        w.f32(1.0);
        w.raw(&[0, 0, 0, 0]);
        let bad = w.finish();
        let mut body = wire::CompressedPullReplyBody::decode(&bad).unwrap();
        assert!(body.next_entry().unwrap().is_err());
        assert!(Message::decode(&bad).is_err());
        // Implausible rank rejected before any dim is read.
        let mut w = Writer::new();
        wire::compressed_pull_reply_header(&mut w, 0, 0, 1);
        w.u32(0); // key
        w.u32(17); // rank > 16
        let bad = w.finish();
        let mut body = wire::CompressedPullReplyBody::decode(&bad).unwrap();
        assert!(body.next_entry().unwrap().is_err());
        // Unknown codec byte in the request rejected by the owned
        // decoder.
        let mut w = Writer::new();
        w.u8(22); // T_COMPRESSED_PULL
        w.u32(0);
        w.u64(0);
        w.u8(9); // bogus codec
        w.u64(0);
        w.u32(0);
        assert!(Message::decode(&w.finish()).is_err());
    }

    #[test]
    fn prop_push_roundtrip() {
        prop::run(40, 0x3355, |g| {
            let n = g.usize(0, 5);
            let entries: Vec<(u32, Tensor)> = (0..n)
                .map(|i| {
                    let len = g.usize(1, 64);
                    (i as u32, Tensor::from_vec(&[len], g.vec_f32(len, -10.0, 10.0)))
                })
                .collect();
            roundtrip(Message::Push {
                worker: g.u64(0, 100) as u32,
                step: g.u64(0, 1 << 40),
                seq: g.u64(0, 1 << 40),
                epoch: g.u64(0, 1 << 20),
                entries,
            });
        });
    }
}
