//! Command-line interface — the leader entrypoint.
//!
//! Subcommands:
//!   info                         list artifacts and platform
//!   advisor-minibatch            §3.1: X_mini sweep + per-layer ILP
//!   advisor-gpus                 §3.2: Lemma 3.1 sizing
//!   advisor-ps                   §3.3: Lemma 3.2 sizing
//!   advisor-backend              PS vs allreduce backend selection
//!   train                        local training on one artifact
//!   train-dist                   in-process distributed cluster
//!   ps / worker                  one role of a real multi-machine job

use std::path::PathBuf;

use crate::advisor::{self, netdefs};
use crate::coordinator::{distributed, local};
use crate::ps::compress::{CodecKind, PullCodec};
use crate::runtime::exec::Runtime;
use crate::sim::device::DeviceModel;
use crate::util::args::{ArgSpec, Parsed};
use crate::util::bench::Table;

fn net_by_name(name: &str) -> Result<netdefs::Network, String> {
    Ok(match name {
        "alexnet" => netdefs::alexnet(),
        "vgg16" => netdefs::vgg16(),
        "cnn_lite" => netdefs::cnn_lite(),
        other => return Err(format!("unknown network {other:?} (alexnet|vgg16|cnn_lite)")),
    })
}

fn artifacts_dir(p: &crate::util::args::Parsed) -> PathBuf {
    PathBuf::from(p.str("artifacts"))
}

pub fn cli_main() -> i32 {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

const USAGE: &str = "dtlsda — distributed training of large-scale deep architectures

subcommands:
  info               list artifacts and runtime platform
  advisor-minibatch  optimal X_mini + per-layer conv algorithms (Eq. 6)
  advisor-gpus       GPU count / efficiency estimates (Lemma 3.1)
  advisor-ps         parameter-server count (Lemma 3.2)
  advisor-backend    ps vs allreduce backend + topology selection
  train              local training on a train_step artifact
  train-dist         distributed training (in-process cluster)
  ps                 run one parameter-server role (real deployment)
  serve              serving-tier QPS benchmark (snapshot reads)

run `dtlsda <subcommand> --help` for options.";

pub fn run(argv: &[String]) -> Result<(), String> {
    let Some(cmd) = argv.first() else {
        return Err(USAGE.to_string());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "info" => cmd_info(rest),
        "advisor-minibatch" => cmd_advisor_minibatch(rest),
        "advisor-gpus" => cmd_advisor_gpus(rest),
        "advisor-ps" => cmd_advisor_ps(rest),
        "advisor-backend" => cmd_advisor_backend(rest),
        "train" => cmd_train(rest),
        "train-dist" => cmd_train_dist(rest),
        "ps" => cmd_ps_role(rest),
        "serve" => cmd_serve(rest),
        "--help" | "-h" | "help" => Err(USAGE.to_string()),
        other => Err(format!("unknown subcommand {other:?}\n\n{USAGE}")),
    }
}

fn cmd_info(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dtlsda info", "list artifacts and platform")
        .opt("artifacts", Some("artifacts"), "artifacts directory");
    let p = spec.parse(argv)?;
    let rt = Runtime::new(&artifacts_dir(&p))?;
    println!("platform: {}", rt.platform());
    let mut t = Table::new(&["artifact", "kind", "batch", "params"]);
    for a in &rt.index.artifacts {
        t.row(&[
            a.name.clone(),
            a.kind.clone(),
            a.batch.to_string(),
            a.num_params.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_advisor_minibatch(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dtlsda advisor-minibatch", "Eq. 6 mini-batch optimization")
        .opt("net", Some("alexnet"), "network (alexnet|vgg16|cnn_lite)")
        .opt("mem-gb", Some("12"), "device memory in GB")
        .opt("candidates", Some("16,32,64,128,256,384,512"), "batch sizes to sweep");
    let p = spec.parse(argv)?;
    let net = net_by_name(&p.str("net"))?;
    let mut dev = DeviceModel::k80();
    dev.mem_bytes = (p.f64("mem-gb") * (1u64 << 30) as f64) as usize;
    let cands: Vec<usize> = p
        .str("candidates")
        .split(',')
        .map(|s| s.trim().parse().map_err(|e| format!("bad candidate: {e}")))
        .collect::<Result<_, _>>()?;

    let Some(plan) = advisor::optimize_minibatch(&net, &dev, &cands) else {
        return Err("no feasible mini-batch size on this device".into());
    };
    let mut t = Table::new(&["X_mini", "feasible", "step_ms", "imgs/s", "algos", "ws_MB"]);
    for (b, lp) in &plan.sweep {
        match lp {
            Some(lp) => t.row(&[
                b.to_string(),
                "yes".into(),
                format!("{:.1}", lp.step_time * 1e3),
                format!("{:.0}", lp.xmini as f64 / lp.step_time),
                lp.algos.iter().map(|a| a.name().chars().next().unwrap()).collect(),
                format!("{:.0}", lp.workspace_bytes as f64 / 1e6),
            ]),
            None => t.row(&[b.to_string(), "no".into(), "-".into(), "-".into(), "-".into(), "-".into()]),
        }
    }
    t.print();
    println!(
        "\nrecommended X_mini = {} ({} algos: {:?})",
        plan.best.xmini,
        net.name,
        plan.best.algos.iter().map(|a| a.name()).collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_advisor_gpus(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dtlsda advisor-gpus", "Lemma 3.1 multi-GPU sizing")
        .opt("ro", Some("0.1"), "measured overhead ratio R_O = T_O/T_C")
        .opt("speedup", None, "target speedup (prints required G)")
        .opt("alpha", None, "target efficiency with --gpus (prints max R_O)")
        .opt("gpus", None, "GPU count for --alpha / efficiency table");
    let p = spec.parse(argv)?;
    let r_o = p.f64("ro");
    if let Some(s) = p.get("speedup") {
        let target: f64 = s.parse().map_err(|e| format!("bad speedup: {e}"))?;
        match advisor::lemmas::gpus_for_speedup(target, r_o) {
            Some(g) => println!(
                "target {target}x at R_O={r_o}: G = {g} (efficiency {:.1}%)",
                advisor::efficiency(g, r_o) * 100.0
            ),
            None => println!(
                "target {target}x unreachable: speedup caps at {:.2}x as G->inf",
                (1.0 + r_o) / r_o
            ),
        }
        return Ok(());
    }
    if let (Some(a), Some(g)) = (p.get("alpha"), p.get("gpus")) {
        let alpha: f64 = a.parse().map_err(|e| format!("bad alpha: {e}"))?;
        let g: usize = g.parse().map_err(|e| format!("bad gpus: {e}"))?;
        println!(
            "G={g}, target α={alpha}: overhead must satisfy R_O <= {:.4}",
            advisor::max_overhead_ratio(g, alpha)
        );
        return Ok(());
    }
    let mut t = Table::new(&["G", "efficiency", "speedup"]);
    for g in [1usize, 2, 4, 8, 16, 32] {
        t.row(&[
            g.to_string(),
            format!("{:.1}%", advisor::efficiency(g, r_o) * 100.0),
            format!("{:.2}x", advisor::speedup(g, r_o)),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_advisor_ps(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dtlsda advisor-ps", "Lemma 3.2 parameter-server sizing")
        .opt("params-mb", Some("244"), "parameter size S_p in MB (AlexNet f32 ≈ 244)")
        .opt("workers", Some("8"), "number of workers N_w")
        .opt("bw-gbps", Some("10"), "per-server network bandwidth, Gbit/s")
        .opt("tc", Some("2.0"), "compute seconds per round T_C")
        .opt("codec", Some("none"), "gradient codec: none|topk[:fraction]|quant8|quant8sr")
        .opt("pull-codec", Some("none"), "parameter pull codec: none|quant8|quant8-delta")
        .opt(
            "replicas",
            Some("1"),
            "chain copies per shard R (failover; R-1 replicas). The fleet \
             is elastic at runtime (train-dist --add-server/--remove-server \
             grows/retires chain tails), so size for the steady-state R",
        )
        .opt(
            "serve-qps",
            None,
            "also size the read tier: replicas needed to sustain this many \
             whole-model snapshot pulls per second",
        )
        .opt("serve-codec", Some("none"), "serving codec for --serve-qps: none|quant8");
    let p = spec.parse(argv)?;
    let s_p = p.f64("params-mb") * 1e6;
    let n_w = p.usize("workers");
    let b_ps = p.f64("bw-gbps") * 1e9 / 8.0;
    let t_c = p.f64("tc");
    let codec = CodecKind::parse(&p.str("codec"))?;
    let pull = PullCodec::parse(&p.str("pull-codec"))?;
    let replicas = p.usize("replicas").max(1);
    let n_ps = advisor::num_param_servers(s_p, n_w, b_ps, t_c);
    println!("Lemma 3.2: N_ps = ceil(2 S_p N_w / (B_ps T_C)) = {n_ps}");
    let n_rec = if codec == CodecKind::None && pull == PullCodec::None {
        n_ps
    } else {
        let n_c =
            advisor::lemmas::num_param_servers_with_codecs(s_p, n_w, b_ps, t_c, codec, pull);
        println!(
            "per-direction traffic: {} pulls ({:.1} MB) + {} pushes ({:.1} MB) \
             replace 2 S_p = {:.1} MB: N_ps = {n_c}",
            pull.name(),
            pull.effective_pull_bytes(s_p) / 1e6,
            codec.name(),
            codec.effective_push_bytes(s_p) / 1e6,
            2.0 * s_p / 1e6
        );
        n_c
    };
    let n_rec = if replicas > 1 {
        let n_r = advisor::lemmas::num_param_servers_replicated_with_codecs(
            s_p, n_w, b_ps, t_c, codec, pull, replicas,
        );
        println!(
            "with {replicas}-way chain replication (push stream relayed once, pulls \
             served once by the head): N_ps = {n_r} shards, {} physical servers",
            advisor::lemmas::num_physical_servers(n_r, replicas)
        );
        n_r
    } else {
        n_rec
    };
    let mut t = Table::new(&["N_ps", "round I/O (s)", "hidden?"]);
    for n in 1..=(n_rec + 2) {
        let io = advisor::lemmas::ps_round_io_time_replicated_with_codecs(
            s_p, n_w, b_ps, n, codec, pull, replicas,
        );
        t.row(&[
            n.to_string(),
            format!("{io:.3}"),
            if io <= t_c { "yes".into() } else { "no".into() },
        ]);
    }
    t.print();
    if let Some(q) = p.get("serve-qps") {
        let target: f64 = q.parse().map_err(|e| format!("bad serve-qps {q:?}: {e}"))?;
        if target <= 0.0 {
            return Err("bad serve-qps: must be positive".into());
        }
        let serve_codec = PullCodec::parse(&p.str("serve-codec"))?;
        let per = advisor::lemmas::serve_qps_per_replica(s_p, b_ps, serve_codec);
        let n = advisor::lemmas::num_serve_replicas(s_p, b_ps, serve_codec, target);
        println!(
            "serving lemma: one replica sustains B / codec_pull(S_p) = {per:.1} \
             whole-model QPS ({} codec); {target} QPS needs {n} read replica{}",
            serve_codec.name(),
            if n == 1 { "" } else { "s" }
        );
    }
    println!(
        "(run `dtlsda advisor-backend` with the same inputs to check whether a \
         serverless allreduce beats this PS tier)"
    );
    Ok(())
}

fn cmd_advisor_backend(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new(
        "dtlsda advisor-backend",
        "choose ps vs allreduce from Lemma 3.2's inputs",
    )
    .opt("params-mb", Some("244"), "parameter size S_p in MB (AlexNet f32 ≈ 244)")
    .opt("workers", Some("8"), "number of workers N_w")
    .opt("bw-gbps", Some("10"), "per-node network bandwidth, Gbit/s")
    .opt("tc", Some("2.0"), "compute seconds per round T_C")
    .opt("latency-us", Some("100"), "per-message link latency α, microseconds")
    .opt(
        "measured",
        None,
        "BENCH_ps_hotpath.json to calibrate α and B from recorded \
         allreduce rows (overrides --bw-gbps/--latency-us)",
    );
    let p = spec.parse(argv)?;
    let s_p = p.f64("params-mb") * 1e6;
    let n_w = p.usize("workers");
    let t_c = p.f64("tc");
    let (b, alpha) = match p.get("measured") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
            let cal = advisor::lemmas::calibrate_from_bench(&text)
                .map_err(|e| format!("calibrate from {path}: {e}"))?;
            println!(
                "calibrated from {path}: α = {:.1} µs, B = {:.2} Gbit/s{}",
                cal.alpha_s * 1e6,
                cal.bandwidth_bps * 8.0 / 1e9,
                if cal.fitted { "" } else { " (degenerate bench rows — defaults kept)" }
            );
            (cal.bandwidth_bps, cal.alpha_s)
        }
        None => (p.f64("bw-gbps") * 1e9 / 8.0, p.f64("latency-us") * 1e-6),
    };
    let c = advisor::lemmas::choose_backend(s_p, n_w, b, t_c, alpha);
    let mut t = Table::new(&["candidate", "round comm (s)", "hidden?", "extra machines"]);
    let hidden = |io: f64| if io <= t_c { "yes".to_string() } else { "no".to_string() };
    t.row(&[
        format!("ps (N_ps={})", c.n_ps),
        format!("{:.3}", c.ps_time_s),
        hidden(c.ps_time_s),
        c.n_ps.to_string(),
    ]);
    t.row(&[
        "allreduce-ring".into(),
        format!("{:.3}", c.ring_time_s),
        hidden(c.ring_time_s),
        "0".into(),
    ]);
    t.row(&[
        "allreduce-tree".into(),
        format!("{:.3}", c.tree_time_s),
        hidden(c.tree_time_s),
        "0".into(),
    ]);
    // Reported for comparison; the recommendation sticks to ring/tree
    // (the closed form flatters hd — see `lemmas::hd_allreduce_time`).
    t.row(&[
        "allreduce-hd".into(),
        format!("{:.3}", c.hd_time_s),
        hidden(c.hd_time_s),
        "0".into(),
    ]);
    t.print();
    match c.backend {
        distributed::Backend::Allreduce => println!(
            "recommended: train-dist --backend allreduce --topology {} --sync \
             (beats the {}-server PS round with zero servers)",
            c.topology.name(),
            c.n_ps
        ),
        distributed::Backend::Ps => println!(
            "recommended: train-dist --backend ps --servers {} \
             (best collective, {}, still needs {:.3} s/round)",
            c.n_ps,
            c.topology.name(),
            c.ring_time_s.min(c.tree_time_s)
        ),
    }
    let eps = advisor::lemmas::DEFAULT_OVERLAP_EPSILON_S;
    let coll = c.ring_time_s.min(c.tree_time_s);
    let overlapped = advisor::lemmas::overlapped_round_time(coll, t_c, eps);
    let verdict = if coll > t_c {
        " — comm-bound: overlap only hides compute; compress or add bandwidth"
    } else {
        " — compute-bound: the collective hides behind T_C"
    };
    println!(
        "overlap (--bucket-bytes): round ≈ max(T_comm, T_C) + ε \
         = max({coll:.3}, {t_c:.3}) + {eps:.3} = {overlapped:.3} s{verdict}"
    );
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dtlsda train", "local training")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .opt("artifact", Some("cnn_gemm_b32_train"), "train_step artifact")
        .opt("steps", Some("50"), "training steps")
        .opt("lr", Some("0.02"), "learning rate")
        .opt("seed", Some("1"), "data seed")
        .opt("eval", None, "eval_step artifact to run afterwards")
        .opt("prefetch", Some("2"), "loader queue depth (0 = unpipelined)")
        .opt("log-every", Some("10"), "loss log cadence");
    let p = spec.parse(argv)?;
    let rt = Runtime::new(&artifacts_dir(&p))?;
    let cfg = local::LocalConfig {
        artifact: p.str("artifact"),
        steps: p.usize("steps"),
        lr: p.f64("lr") as f32,
        seed: p.u64("seed"),
        prefetch_depth: p.usize("prefetch"),
        log_every: p.usize("log-every"),
    };
    let (params, stats) = local::train_local(&rt, &cfg)?;
    println!(
        "trained {} for {} steps: loss {:.4} -> {:.4}, {:.1} samples/s, R_O={:.3}",
        cfg.artifact,
        cfg.steps,
        stats.losses.first().unwrap_or(&f32::NAN),
        stats.losses.last().unwrap_or(&f32::NAN),
        stats.throughput,
        stats.profiler.r_o()
    );
    print!("{}", stats.profiler.report());
    if let Some(eval) = p.get("eval") {
        let report = local::evaluate(&rt, eval, &params, 1 << 20, 2, cfg.seed)?;
        println!(
            "eval: loss {:.4}, top-1 error {:.1}% over {} samples",
            report.mean_loss,
            report.error_rate * 100.0,
            report.samples
        );
    }
    Ok(())
}

fn parse_opt_u64(p: &Parsed, key: &str) -> Result<Option<u64>, String> {
    match p.get(key) {
        Some(v) => v.parse::<u64>().map(Some).map_err(|e| format!("bad {key} {v:?}: {e}")),
        None => Ok(None),
    }
}

fn cmd_train_dist(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dtlsda train-dist", "distributed training (loopback cluster)")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .opt("artifact", Some("cnn_gemm_b32_grad"), "grad_step artifact")
        .opt("workers", Some("2"), "worker count N_w")
        .opt("servers", Some("2"), "parameter-server count N_ps")
        .opt("steps", Some("10"), "steps per worker")
        .opt("lr", Some("0.02"), "learning rate")
        .opt("momentum", Some("0"), "server-side momentum")
        .opt("codec", Some("none"), "gradient codec: none|topk[:fraction]|quant8|quant8sr")
        .opt("pull-codec", Some("none"), "parameter pull codec: none|quant8|quant8-delta")
        .opt(
            "fault-plan",
            None,
            "chaos spec, e.g. seed=7,drop=0.05,dup=0.02,trunc=0.01,recv_drop=0.02,\
             latency_ms=3,latency_p=0.5,disconnect_after=40",
        )
        .opt(
            "retry",
            Some("auto"),
            "client retries per op (reconnect + replay); auto = 40 with \
             --replicas >= 2, 8 with a fault plan, else 0",
        )
        .opt("restarts", Some("0"), "worker restarts tolerated (checkpoint-based)")
        .opt("checkpoint-dir", None, "directory for restart checkpoints")
        .opt("barrier-timeout-ms", None, "sync-barrier wait before retryable error")
        .opt("replicas", Some("1"), "chain copies per PS shard (R>=2 enables failover)")
        .opt("ps-heartbeat-ms", Some("100"), "server-supervisor heartbeat cadence")
        .opt(
            "add-server",
            None,
            "grow the thinnest shard chain by one catch-up replica once \
             any worker reaches this step (elastic scale-out)",
        )
        .opt(
            "remove-server",
            None,
            "retire the tail of the longest shard chain once any worker \
             reaches this step (elastic scale-in)",
        )
        .opt(
            "ps-deadline-ms",
            None,
            "worker-side reply deadline; default: bounded when replicated \
             (sync: barrier timeout + 5s, async: 10s), else unbounded; \
             for --backend allreduce, the collective's per-receive deadline",
        )
        .opt(
            "backend",
            Some("ps"),
            "aggregation backend: ps (sharded parameter servers) or \
             allreduce (peer-to-peer collective, requires --sync; \
             `advisor-backend` compares them)",
        )
        .opt(
            "topology",
            Some("auto"),
            "allreduce topology: ring|tree|hd|auto (auto = Lemma 3.2 cost model)",
        )
        .opt(
            "bucket-bytes",
            None,
            "fixed-byte gradient bucket size enabling the overlapped \
             committer: buckets ship in reverse layer order on a \
             dedicated comms thread (allreduce) or via a split \
             push_send/push_wait (ps) while compute folds the next \
             bucket; results are bit-identical to the serial commit",
        )
        .opt(
            "serve-publish-every",
            None,
            "publish a read-only serve snapshot every N store updates \
             (sync mode publishes at step boundaries regardless, so the \
             chain stays byte-identical; see the serve subcommand)",
        )
        .flag("sync", "synchronous SGD (default async)")
        .flag(
            "straggler-backpressure",
            "auto-enable backup workers when a worker is persistently \
             flagged as a straggler (ps sync only)",
        );
    let p = spec.parse(argv)?;
    let backend = distributed::Backend::parse(&p.str("backend"))?;
    let topology = match p.str("topology").as_str() {
        "auto" => None,
        other => Some(crate::net::collective::Topology::parse(other)?),
    };
    if backend == distributed::Backend::Allreduce && !p.flag("sync") {
        return Err("--backend allreduce requires --sync: the collective is the barrier".into());
    }
    let bucket_bytes = match p.get("bucket-bytes") {
        Some(v) => {
            let bb: usize = v.parse().map_err(|e| format!("bad bucket-bytes {v:?}: {e}"))?;
            if bb == 0 {
                return Err("bad bucket-bytes: must be positive (0 disables nothing)".into());
            }
            Some(bb)
        }
        None => None,
    };
    let fault_plan = match p.get("fault-plan") {
        Some(spec) => Some(crate::net::fault::FaultPlan::parse(spec)?),
        None => None,
    };
    let replicas = p.usize("replicas").max(1);
    // A fault plan without retries would fail on the first injected
    // drop — and a replicated run without retries would fail at the
    // first failover (clients recover by reconnect-and-replay). The
    // replicated budget is larger because worst-case failover (wedged
    // head: lease detection at probe-timeout granularity plus the
    // replica's bounded pre-takeover drain) spans seconds that the
    // backed-off reconnects must outlast. An explicit value — `0`
    // included, for fail-fast runs — is always honored.
    let retry = match p.str("retry").as_str() {
        "auto" if replicas > 1 => 40,
        "auto" if fault_plan.is_some() => 8,
        "auto" => 0,
        v => v.parse::<usize>().map_err(|e| format!("bad retry {v:?}: {e}"))?,
    };
    let cfg = distributed::DistConfig {
        grad_artifact: p.str("artifact"),
        n_workers: p.usize("workers"),
        n_servers: p.usize("servers"),
        steps_per_worker: p.usize("steps"),
        lr: p.f64("lr") as f32,
        momentum: p.f64("momentum") as f32,
        sync: p.flag("sync"),
        seed: 1,
        codec: CodecKind::parse(&p.str("codec"))?,
        pull_codec: PullCodec::parse(&p.str("pull-codec"))?,
        fault_plan,
        retry,
        max_worker_restarts: p.usize("restarts"),
        checkpoint_dir: p.get("checkpoint-dir").map(PathBuf::from),
        barrier_timeout_ms: parse_opt_u64(&p, "barrier-timeout-ms")?,
        straggler_factor: 2.0,
        replicas,
        ps_heartbeat_ms: p.u64("ps-heartbeat-ms"),
        add_server_at: parse_opt_u64(&p, "add-server")?,
        remove_server_at: parse_opt_u64(&p, "remove-server")?,
        read_deadline_ms: parse_opt_u64(&p, "ps-deadline-ms")?,
        backend,
        topology,
        bucket_bytes,
        straggler_backpressure: p.flag("straggler-backpressure"),
        serve_publish_every: parse_opt_u64(&p, "serve-publish-every")?,
    };
    let report = distributed::run_distributed(&PathBuf::from(p.str("artifacts")), &cfg)?;
    match cfg.backend {
        distributed::Backend::Ps => println!(
            "distributed run [ps]: {} workers x {} steps, {} servers ({}): {:.1} samples/s",
            cfg.n_workers,
            cfg.steps_per_worker,
            cfg.n_servers,
            if cfg.sync { "sync" } else { "async" },
            report.throughput
        ),
        distributed::Backend::Allreduce => println!(
            "distributed run [allreduce-{}]: {} ranks x {} steps, 0 servers (sync): \
             {:.1} samples/s, {} group reform(s)",
            cfg.topology.map(|t| t.name()).unwrap_or("auto"),
            cfg.n_workers,
            cfg.steps_per_worker,
            report.throughput,
            report.ps_epoch
        ),
    }
    if let Some(bb) = cfg.bucket_bytes {
        println!(
            "overlapped commits: --bucket-bytes {bb} (buckets stream in reverse \
             layer order while compute folds the next; bit-identical to serial)"
        );
    }
    for (w, losses) in report.worker_losses.iter().enumerate() {
        println!(
            "worker {w}: loss {:.4} -> {:.4}, R_O={:.3}",
            losses.first().unwrap_or(&f32::NAN),
            losses.last().unwrap_or(&f32::NAN),
            report.worker_r_o[w]
        );
    }
    if cfg.backend == distributed::Backend::Ps {
        let (pulls, pushes, updates) = report.ps_stats;
        println!(
            "ps: pulls={pulls} pushes={pushes} updates={updates} imbalance={:.3}",
            report.router_imbalance
        );
    }
    if cfg.replicas > 1 {
        println!(
            "ps replication: {} copies per shard, routing epoch {} ({})",
            cfg.replicas,
            report.ps_epoch,
            if report.ps_epoch == 0 { "no failover" } else { "failovers occurred" }
        );
    }
    println!(
        "push wire traffic: {:.2} MB total ({} codec)",
        report.push_wire_bytes as f64 / 1e6,
        cfg.codec.name()
    );
    println!(
        "pull wire traffic: {:.2} MB total ({} pull codec)",
        report.pull_wire_bytes as f64 / 1e6,
        cfg.pull_codec.name()
    );
    if cfg.fault_plan.is_some() || report.worker_restarts.iter().any(|&r| r > 0) {
        println!(
            "fault recovery: restarts per worker {:?} (chaos plan {})",
            report.worker_restarts,
            if cfg.fault_plan.is_some() { "active" } else { "off" }
        );
    }
    if report.stragglers.is_empty() {
        println!("stragglers: none (mean step s per worker: {:?})", report.worker_step_s);
    } else {
        println!(
            "stragglers: workers {:?} exceed {}x the median step time ({:?} s)",
            report.stragglers, cfg.straggler_factor, report.worker_step_s
        );
    }
    Ok(())
}

/// Real multi-machine role: run one parameter server on a fixed port.
fn cmd_ps_role(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("dtlsda ps", "serve one parameter-server shard")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .opt("family", Some("cnn"), "model family to serve")
        .opt("bind", Some("0.0.0.0:7070"), "listen address")
        .opt("shard", Some("0"), "this server's shard index")
        .opt("num-shards", Some("1"), "total shard count")
        .opt("lr", Some("0.02"), "learning rate")
        .opt("momentum", Some("0"), "momentum")
        .opt("sync-workers", Some("0"), "if >0, sync mode with this many workers");
    let p = spec.parse(argv)?;
    let index = crate::runtime::artifact::ArtifactIndex::load(&artifacts_dir(&p))?;
    let manifest = index.manifest(&p.str("family"))?;
    let init = manifest.load_init()?;
    let router = crate::ps::router::Router::new(&manifest.byte_sizes(), p.usize("num-shards"));
    let shard = p.usize("shard");
    let momentum = p.f64("momentum") as f32;
    let opt = if momentum > 0.0 {
        crate::ps::shard::Optimizer::Momentum { lr: p.f64("lr") as f32, mu: momentum }
    } else {
        crate::ps::shard::Optimizer::Sgd { lr: p.f64("lr") as f32 }
    };
    let mut store = crate::ps::shard::ShardStore::new(opt);
    for &k in router.keys_of(shard) {
        store.insert(k, init[k as usize].clone());
    }
    let sync_workers = p.usize("sync-workers");
    let mode = if sync_workers > 0 {
        crate::ps::server::UpdateMode::Sync { expected_workers: sync_workers, backup_workers: 0 }
    } else {
        crate::ps::server::UpdateMode::Async
    };
    let srv = PsServerRoleGuard(crate::ps::server::PsServerHandle::spawn_tcp(
        &p.str("bind"),
        store,
        mode,
    )?);
    crate::info!(
        "ps",
        "serving",
        addr = srv.0.addr,
        shard = shard,
        keys = router.keys_of(shard).len()
    );
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

struct PsServerRoleGuard(crate::ps::server::PsServerHandle);

/// One measured serving configuration (all clients merged).
struct ServeRow {
    qps: f64,
    p50_ms: f64,
    p99_ms: f64,
    wire_bytes: u64,
}

/// Closed-loop round: `clients` threads each issue `requests`
/// whole-model snapshot pulls back-to-back; QPS is total completions
/// over wall time, latencies are merged across clients.
fn serve_round(
    addr: &str,
    codec: PullCodec,
    clients: usize,
    requests: usize,
) -> Result<ServeRow, String> {
    use std::time::Instant;
    let start = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..clients {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<(Vec<f64>, u64), String> {
            let t = crate::net::transport::connect(&addr)?;
            let mut c = crate::ps::serve::ServeClient::new(Box::new(t));
            c.set_codec(codec);
            let redial = addr.clone();
            c.set_reconnect(Box::new(move |_| {
                crate::net::transport::connect(&redial)
                    .map(|t| Box::new(t) as Box<dyn crate::net::transport::Transport>)
            }));
            let mut lat = Vec::with_capacity(requests);
            for _ in 0..requests {
                let t0 = Instant::now();
                c.pull_model()?;
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            Ok((lat, c.wire_bytes))
        }));
    }
    let mut lat = Vec::new();
    let mut wire_bytes = 0u64;
    for h in handles {
        let (l, b) = h.join().map_err(|_| "serve client panicked".to_string())??;
        lat.extend(l);
        wire_bytes += b;
    }
    let wall = start.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    let pct = |q: f64| lat[((lat.len() - 1) as f64 * q).round() as usize];
    Ok(ServeRow {
        qps: lat.len() as f64 / wall.max(1e-9),
        p50_ms: pct(0.50),
        p99_ms: pct(0.99),
        wire_bytes,
    })
}

/// Background training load for the serve-during-training row: each
/// pusher streams dense `Push` frames (unfenced epoch sentinel) over
/// its own connection until `stop`, returning its push count.
fn spawn_serve_pushers(
    addr: &str,
    n: usize,
    n_keys: usize,
    elems: usize,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
) -> Vec<std::thread::JoinHandle<Result<u64, String>>> {
    use std::sync::atomic::Ordering;
    (0..n)
        .map(|i| {
            let addr = addr.to_string();
            let stop = stop.clone();
            std::thread::spawn(move || -> Result<u64, String> {
                let mut t = crate::net::transport::connect(&addr)?;
                let grad = crate::tensor::Tensor::from_vec(&[elems], vec![1e-3; elems]);
                let mut seq = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    seq += 1;
                    let msg = crate::net::message::Message::Push {
                        worker: 1_000 + i as u32,
                        step: seq,
                        seq,
                        epoch: u64::MAX,
                        entries: vec![((seq % n_keys as u64) as u32, grad.clone())],
                    };
                    t.send(&msg)?;
                    match t.recv()? {
                        crate::net::message::Message::PushAck { .. } => {}
                        crate::net::message::Message::Error { what } => return Err(what),
                        other => return Err(format!("unexpected push reply {other:?}")),
                    }
                }
                Ok(seq)
            })
        })
        .collect()
}

/// Closed-loop QPS benchmark of the read-only serving tier. Spawns one
/// TCP parameter server over a deterministic synthetic model and
/// measures whole-model snapshot pulls per second per codec — idle,
/// and again while training pushes hammer the same store with snapshot
/// publishes on a cadence — then writes the JSON that CI's bench-trend
/// gates consume.
fn cmd_serve(argv: &[String]) -> Result<(), String> {
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    use crate::util::json::Json;

    let spec = ArgSpec::new("dtlsda serve", "serving-tier QPS benchmark (snapshot reads)")
        .opt("params-mb", Some("8"), "synthetic model size in MB")
        .opt("keys", Some("64"), "tensors the model splits into")
        .opt("clients", Some("4"), "concurrent closed-loop serve clients")
        .opt("requests", Some("50"), "whole-model pulls per client")
        .opt(
            "train-pushers",
            Some("2"),
            "concurrent training pushers for the serve-during-training row",
        )
        .opt(
            "publish-every",
            Some("8"),
            "snapshot publish cadence (store updates) while training pushes land",
        )
        .opt("out", Some("BENCH_serve.json"), "output JSON path");
    let p = spec.parse(argv)?;
    let smoke = std::env::var("DTLSDA_BENCH_SMOKE").is_ok();
    let params_mb = if smoke { 1.0 } else { p.f64("params-mb") };
    let n_keys = p.usize("keys").max(1);
    let clients = if smoke { 2 } else { p.usize("clients").max(1) };
    let requests = if smoke { 8 } else { p.usize("requests").max(1) };
    let pushers = if smoke { 1 } else { p.usize("train-pushers").max(1) };
    let publish_every = p.u64("publish-every").max(1);

    let elems = (((params_mb * 1e6 / 4.0) / n_keys as f64).max(1.0)) as usize;
    let mut store =
        crate::ps::shard::ShardStore::new(crate::ps::shard::Optimizer::Sgd { lr: 0.01 });
    for k in 0..n_keys as u32 {
        let data: Vec<f32> =
            (0..elems).map(|i| ((k as usize * 31 + i) % 251) as f32 * 0.01 - 1.0).collect();
        store.insert(k, crate::tensor::Tensor::from_vec(&[elems], data));
    }
    let mut srv = crate::ps::server::PsServerHandle::spawn_tcp(
        "127.0.0.1:0",
        store,
        crate::ps::server::UpdateMode::Async,
    )?;
    srv.shared.store.publish_version();
    let addr = srv.addr.to_string();
    println!(
        "serving {n_keys} keys x {elems} elems (~{:.1} MB) at {addr}: \
         {clients} clients x {requests} pulls per row",
        (n_keys * elems * 4) as f64 / 1e6
    );

    let dense = serve_round(&addr, PullCodec::None, clients, requests)?;
    let quant = serve_round(&addr, PullCodec::Quant8, clients, requests)?;

    // Serve-during-training: enable cadence publishing, hammer the
    // store with pushes, and measure the same closed loop — pins must
    // keep serving publish-time bytes while versions churn underneath.
    srv.shared.set_serve_publish_every(publish_every);
    let stop = Arc::new(AtomicBool::new(false));
    let push_threads = spawn_serve_pushers(&addr, pushers, n_keys, elems, stop.clone());
    let during = serve_round(&addr, PullCodec::Quant8, clients, requests)?;
    stop.store(true, Ordering::Relaxed);
    let mut train_pushes = 0u64;
    for h in push_threads {
        train_pushes += h.join().map_err(|_| "pusher panicked".to_string())??;
    }

    let wire_ratio = dense.wire_bytes as f64 / (quant.wire_bytes as f64).max(1.0);
    let mut t = Table::new(&["row", "codec", "clients", "QPS", "p50 ms", "p99 ms", "wire MB"]);
    let mut results = Vec::new();
    for (name, codec, row) in [
        ("serve", "none", &dense),
        ("serve", "quant8", &quant),
        ("serve-during-training", "quant8", &during),
    ] {
        t.row(&[
            name.into(),
            codec.into(),
            clients.to_string(),
            format!("{:.1}", row.qps),
            format!("{:.3}", row.p50_ms),
            format!("{:.3}", row.p99_ms),
            format!("{:.2}", row.wire_bytes as f64 / 1e6),
        ]);
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(name.into()));
        m.insert("codec".to_string(), Json::Str(codec.into()));
        m.insert("clients".to_string(), Json::Num(clients as f64));
        m.insert("requests".to_string(), Json::Num((clients * requests) as f64));
        m.insert("qps".to_string(), Json::Num(row.qps));
        m.insert("p50_ms".to_string(), Json::Num(row.p50_ms));
        m.insert("p99_ms".to_string(), Json::Num(row.p99_ms));
        m.insert("wire_mb".to_string(), Json::Num(row.wire_bytes as f64 / 1e6));
        results.push(Json::Obj(m));
    }
    t.print();
    println!(
        "quant8 serves {wire_ratio:.1}x fewer bytes per model than dense; \
         {train_pushes} training pushes landed during the serving row"
    );

    let mut root = BTreeMap::new();
    root.insert("results".to_string(), Json::Arr(results));
    root.insert("serve_dense_qps".to_string(), Json::Num(dense.qps));
    root.insert("serve_quant8_qps".to_string(), Json::Num(quant.qps));
    root.insert("serve_during_training_qps".to_string(), Json::Num(during.qps));
    root.insert(
        "serve_wire_ratio_dense_over_quant8".to_string(),
        Json::Num(wire_ratio),
    );
    root.insert("train_pushes_during_serve".to_string(), Json::Num(train_pushes as f64));
    let out = p.str("out");
    std::fs::write(&out, format!("{}\n", Json::Obj(root)))
        .map_err(|e| format!("write {out}: {e}"))?;
    println!("wrote {out}");
    srv.shutdown();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn usage_on_empty() {
        assert!(run(&[]).is_err());
        assert!(run(&argv(&["help"])).unwrap_err().contains("subcommands"));
    }

    #[test]
    fn unknown_subcommand() {
        assert!(run(&argv(&["frobnicate"])).is_err());
    }

    #[test]
    fn advisor_gpus_table() {
        run(&argv(&["advisor-gpus", "--ro", "0.1"])).unwrap();
        run(&argv(&["advisor-gpus", "--ro", "0.1", "--speedup", "3"])).unwrap();
        run(&argv(&["advisor-gpus", "--alpha", "0.8", "--gpus", "4", "--ro", "0"])).unwrap();
    }

    #[test]
    fn advisor_ps_table() {
        run(&argv(&["advisor-ps", "--params-mb", "244", "--workers", "8"])).unwrap();
        run(&argv(&[
            "advisor-ps",
            "--params-mb",
            "244",
            "--workers",
            "8",
            "--codec",
            "topk:0.01",
        ]))
        .unwrap();
        run(&argv(&["advisor-ps", "--codec", "quant8"])).unwrap();
        run(&argv(&["advisor-ps", "--codec", "quant8", "--replicas", "2"])).unwrap();
        run(&argv(&["advisor-ps", "--replicas", "3"])).unwrap();
        run(&argv(&["advisor-ps", "--pull-codec", "quant8"])).unwrap();
        run(&argv(&["advisor-ps", "--codec", "quant8", "--pull-codec", "quant8-delta"]))
            .unwrap();
        run(&argv(&[
            "advisor-ps",
            "--codec",
            "quant8",
            "--pull-codec",
            "quant8",
            "--replicas",
            "2",
        ]))
        .unwrap();
        assert!(run(&argv(&["advisor-ps", "--codec", "bogus"])).is_err());
        assert!(run(&argv(&["advisor-ps", "--pull-codec", "bogus"])).is_err());
    }

    #[test]
    fn advisor_ps_serving_lemma() {
        run(&argv(&["advisor-ps", "--serve-qps", "100"])).unwrap();
        run(&argv(&["advisor-ps", "--serve-qps", "100", "--serve-codec", "quant8"])).unwrap();
        assert!(run(&argv(&["advisor-ps", "--serve-qps", "0"])).is_err());
        assert!(run(&argv(&["advisor-ps", "--serve-qps", "bogus"])).is_err());
        assert!(run(&argv(&["advisor-ps", "--serve-qps", "10", "--serve-codec", "bogus"]))
            .is_err());
    }

    #[test]
    fn serve_bench_writes_gated_json() {
        // A tiny end-to-end run of the serving benchmark: real TCP
        // server, closed-loop clients, training pushers — the JSON it
        // writes must carry the summary keys bench-trend gates on.
        let out = std::env::temp_dir().join(format!("BENCH_serve_test_{}.json", std::process::id()));
        run(&argv(&[
            "serve",
            "--params-mb",
            "0.02",
            "--keys",
            "4",
            "--clients",
            "2",
            "--requests",
            "3",
            "--train-pushers",
            "1",
            "--publish-every",
            "2",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        std::fs::remove_file(&out).ok();
        let j = crate::util::json::Json::parse(&text).unwrap();
        for key in [
            "serve_dense_qps",
            "serve_quant8_qps",
            "serve_during_training_qps",
        ] {
            let v = j.get(key).and_then(crate::util::json::Json::as_f64).unwrap();
            assert!(v > 0.0, "{key} = {v}");
        }
        let ratio = j
            .get("serve_wire_ratio_dense_over_quant8")
            .and_then(crate::util::json::Json::as_f64)
            .unwrap();
        assert!(ratio >= 3.0, "wire ratio {ratio}");
        assert_eq!(j.arr_field("results").unwrap().len(), 3);
    }

    #[test]
    fn train_dist_rejects_bad_retry() {
        // `auto` and explicit numbers parse before any cluster spins
        // up; garbage errors out (cheap to assert — the artifacts
        // lookup fails later on CI, but arg errors surface first).
        let err = run(&argv(&[
            "train-dist",
            "--artifacts",
            "/nonexistent",
            "--retry",
            "bogus",
        ]))
        .unwrap_err();
        assert!(err.contains("bad retry"), "{err}");
    }

    #[test]
    fn train_dist_rejects_bad_pull_codec() {
        // Arg validation fires before the cluster (or artifacts) load.
        let err = run(&argv(&[
            "train-dist",
            "--artifacts",
            "/nonexistent",
            "--pull-codec",
            "bogus",
        ]))
        .unwrap_err();
        assert!(err.contains("unknown pull codec"), "{err}");
    }

    #[test]
    fn advisor_backend_runs() {
        run(&argv(&["advisor-backend"])).unwrap();
        // 1 GbE AlexNet: PS territory. 10 GbE: allreduce. Both must
        // render without error.
        run(&argv(&[
            "advisor-backend",
            "--params-mb",
            "244",
            "--workers",
            "4",
            "--bw-gbps",
            "1",
        ]))
        .unwrap();
        run(&argv(&[
            "advisor-backend",
            "--params-mb",
            "244",
            "--workers",
            "4",
            "--bw-gbps",
            "10",
            "--latency-us",
            "100",
        ]))
        .unwrap();
        assert!(run(&argv(&["advisor-backend", "--workers", "bogus"])).is_err());
    }

    #[test]
    fn train_dist_backend_flag_validation() {
        // allreduce without --sync is rejected before anything spins up.
        let err = run(&argv(&[
            "train-dist",
            "--artifacts",
            "/nonexistent",
            "--backend",
            "allreduce",
        ]))
        .unwrap_err();
        assert!(err.contains("requires --sync"), "{err}");
        // Unknown backend / topology are arg errors, not cluster errors.
        let err = run(&argv(&[
            "train-dist",
            "--artifacts",
            "/nonexistent",
            "--backend",
            "bogus",
        ]))
        .unwrap_err();
        assert!(err.contains("unknown backend"), "{err}");
        let err = run(&argv(&[
            "train-dist",
            "--artifacts",
            "/nonexistent",
            "--backend",
            "allreduce",
            "--sync",
            "--topology",
            "bogus",
        ]))
        .unwrap_err();
        assert!(err.contains("unknown topology"), "{err}");
    }

    #[test]
    fn advisor_backend_measured() {
        // The checked-in fixture calibrates to α = 50 µs, B = 2 GB/s;
        // the AlexNet/4-worker pick at those constants is allreduce
        // (`lemmas::calibration_recovers_pinned_link_constants` pins
        // the numbers; this exercises the CLI path end to end).
        let fixture =
            concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/bench_calibration.json");
        run(&argv(&[
            "advisor-backend",
            "--measured",
            fixture,
            "--params-mb",
            "244",
            "--workers",
            "4",
        ]))
        .unwrap();
        // Missing file and invalid JSON are errors, not silent defaults.
        let err = run(&argv(&["advisor-backend", "--measured", "/nonexistent.json"]))
            .unwrap_err();
        assert!(err.contains("read /nonexistent.json"), "{err}");
    }

    #[test]
    fn train_dist_rejects_bad_bucket_bytes() {
        // Arg validation fires before the cluster (or artifacts) load.
        let err = run(&argv(&[
            "train-dist",
            "--artifacts",
            "/nonexistent",
            "--backend",
            "allreduce",
            "--sync",
            "--bucket-bytes",
            "0",
        ]))
        .unwrap_err();
        assert!(err.contains("bad bucket-bytes"), "{err}");
        let err = run(&argv(&[
            "train-dist",
            "--artifacts",
            "/nonexistent",
            "--bucket-bytes",
            "nope",
        ]))
        .unwrap_err();
        assert!(err.contains("bad bucket-bytes"), "{err}");
    }

    #[test]
    fn advisor_minibatch_runs() {
        run(&argv(&["advisor-minibatch", "--net", "alexnet", "--mem-gb", "4"])).unwrap();
        assert!(run(&argv(&["advisor-minibatch", "--net", "nope"])).is_err());
    }
}
