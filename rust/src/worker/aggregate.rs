//! Backend-agnostic gradient aggregation for the worker loop.
//!
//! `worker::pipeline::run_agg_worker` drives training against any
//! [`GradAggregator`]: the parameter-server backend
//! ([`PsAggregator`], a thin wrapper over [`PsClient`]) or the
//! peer-to-peer collective backend ([`AllreduceAggregator`], over
//! [`net::collective`](crate::net::collective)). The worker loop itself
//! — prefetching loader, profiler, progress counter — does not know
//! which backend it is talking to; `train-dist --backend ps|allreduce`
//! picks the implementation.
//!
//! # Overlapping communication with computation
//!
//! Besides the blocking `commit`, the trait exposes a
//! [`start_commit`](GradAggregator::start_commit) /
//! [`wait_all`](GradAggregator::wait_all) split so the pipeline can
//! ship this step's gradients while it already prefetches and computes
//! the next batch. With `--bucket-bytes` the allreduce backend
//! partitions the parameter list into fixed-byte buckets
//! (layer-order-reversed, so the last-computed gradients ship first)
//! and runs each bucket's collective on a dedicated comms thread:
//! bucket *i* streams while the worker still compresses bucket *i+1*.
//! The PS backend defers its ack collection and sync barrier instead.
//! Either way the arithmetic — fold order, scale, optimizer apply — is
//! byte-for-byte the blocking path's, so overlap-on and overlap-off
//! runs produce bit-identical parameters (pinned by the parity tests).
//!
//! # Parity contract
//!
//! The allreduce backend reproduces the PS sync arithmetic exactly:
//! contributions are compressed with the same per-key codec state a
//! `PsClient` would use (top-k error feedback, the same
//! stochastic-rounding RNG stream per worker id), folded flat in rank
//! order with the PS fold's `axpy(1.0)`/`scatter_axpy(1.0)` adds,
//! scaled by `1/N` like the barrier release, and applied through the
//! same [`Optimizer`] update the shard store runs. With identical
//! seeds, sync PS and allreduce converge to byte-comparable losses —
//! pinned by the backend-parity integration tests.

use std::collections::BTreeMap;

use crate::net::collective::{Collective, Contrib};
use crate::ps::client::PsClient;
use crate::ps::compress::{quantize8, CodecKind, TopK};
use crate::ps::shard::Optimizer;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One step's worth of gradient aggregation, from the worker loop's
/// point of view: refresh parameters before compute, commit gradients
/// after. `commit` must not return until the step is durable for its
/// backend (push acked + barrier passed for PS; collective complete
/// and applied for allreduce).
pub trait GradAggregator {
    /// Refill `params` with the parameters to compute against this
    /// step (in-place; implementations reuse the buffer).
    fn refresh(&mut self, params: &mut Vec<Tensor>) -> Result<(), String>;

    /// Commit one step's gradients. Allreduce backends update `params`
    /// in place (every rank applies the identical mean); the PS
    /// backend leaves them to the next `refresh`.
    fn commit(
        &mut self,
        step: u64,
        params: &mut Vec<Tensor>,
        grads: &[Tensor],
    ) -> Result<(), String>;

    /// Begin committing one step's gradients without waiting for
    /// durability — the overlap half-call. The default is the blocking
    /// [`commit`](GradAggregator::commit); overlapped backends ship
    /// buckets to a comms thread (or defer ack collection) and return
    /// while the wire is still busy. Callers MUST `wait_all` before
    /// the next `refresh` and before reading `params`.
    fn start_commit(
        &mut self,
        step: u64,
        params: &mut Vec<Tensor>,
        grads: &[Tensor],
    ) -> Result<(), String> {
        self.commit(step, params, grads)
    }

    /// Wait until every in-flight `start_commit` is durable and
    /// applied. All-or-nothing: on `Err` no partial bucket has been
    /// applied — `params` still hold the last committed step, so a
    /// group reform replays the failed step exactly once, never twice.
    fn wait_all(&mut self, params: &mut Vec<Tensor>) -> Result<(), String> {
        let _ = params;
        Ok(())
    }

    /// Cumulative gradient-direction wire bytes sent by this worker.
    fn push_wire_bytes(&self) -> u64;

    /// Cumulative parameter-direction wire bytes for this worker.
    fn pull_wire_bytes(&self) -> u64;
}

/// The parameter-server backend: pull from the fleet, push to it,
/// barrier in sync mode. Pure delegation — codec staging, retries,
/// reconnects and epoch fencing all live in [`PsClient`]. The overlap
/// split maps onto the push's two wire phases: `start_commit` sends
/// the (compressed) frames to every shard, `wait_all` collects the
/// acks and runs the sync barrier — so the ack round-trips hide behind
/// the next batch's prefetch and forward pass.
pub struct PsAggregator<'a> {
    client: &'a mut PsClient,
    sync: bool,
    /// An in-flight `start_commit`: step plus a gradient snapshot,
    /// kept because a reconnect mid-wait must replay the dense push
    /// from the original tensors.
    pending: Option<(u64, Vec<Tensor>)>,
}

impl<'a> PsAggregator<'a> {
    pub fn new(client: &'a mut PsClient, sync: bool) -> Self {
        PsAggregator { client, sync, pending: None }
    }
}

impl GradAggregator for PsAggregator<'_> {
    fn refresh(&mut self, params: &mut Vec<Tensor>) -> Result<(), String> {
        self.client.pull_all_into(params)
    }

    fn commit(
        &mut self,
        step: u64,
        _params: &mut Vec<Tensor>,
        grads: &[Tensor],
    ) -> Result<(), String> {
        self.client.push(step, grads)?;
        if self.sync {
            self.client.barrier(step)?;
        }
        Ok(())
    }

    fn start_commit(
        &mut self,
        step: u64,
        _params: &mut Vec<Tensor>,
        grads: &[Tensor],
    ) -> Result<(), String> {
        if self.pending.is_some() {
            return Err("ps push already in flight (missing wait_all)".into());
        }
        self.client.push_send(step, grads)?;
        self.pending = Some((step, grads.to_vec()));
        Ok(())
    }

    fn wait_all(&mut self, _params: &mut Vec<Tensor>) -> Result<(), String> {
        let Some((step, grads)) = self.pending.take() else {
            return Ok(());
        };
        self.client.push_wait(step, &grads)?;
        if self.sync {
            self.client.barrier(step)?;
        }
        Ok(())
    }

    fn push_wire_bytes(&self) -> u64 {
        self.client.push_wire_bytes()
    }

    fn pull_wire_bytes(&self) -> u64 {
        self.client.pull_wire_bytes()
    }
}

/// How the allreduce backend runs its collectives: inline on the
/// worker thread (serial, the PR 8 behavior), or bucketized on a
/// dedicated comms thread so communication overlaps compute.
enum Driver {
    Serial(Collective),
    #[cfg(feature = "overlap-commit")]
    Overlap(overlap::CommitPipe),
}

/// The collective backend: every rank holds the full model, allreduces
/// its (optionally compressed) gradient each step and applies the
/// identical mean locally through the same [`Optimizer`] arithmetic the
/// PS shard store uses. Inherently synchronous — the collective *is*
/// the barrier.
pub struct AllreduceAggregator {
    driver: Driver,
    rank: usize,
    n_ranks: usize,
    optimizer: Optimizer,
    /// Per-key momentum state, lazily created like the shard store's
    /// velocity map — identical update order, identical bytes.
    velocity: Vec<Option<Tensor>>,
    codec: CodecKind,
    /// Per-key top-k compressors (error-feedback residuals), exactly
    /// the per-key state `PsClient::push` keeps.
    topk: BTreeMap<u32, TopK>,
    /// Stochastic-rounding stream for `quant8sr`, seeded per rank the
    /// same way `PsClient` seeds per worker id — same worker, same
    /// gradient, same bytes on either backend.
    sr_rng: Rng,
    /// Initial parameters, handed to the loop's buffer on the first
    /// `refresh`. All ranks must be constructed with identical init.
    init: Option<Vec<Tensor>>,
    /// Key buckets for the overlapped committer (empty when serial).
    buckets: Vec<Vec<usize>>,
}

impl AllreduceAggregator {
    pub fn new(
        collective: Collective,
        optimizer: Optimizer,
        codec: CodecKind,
        init: Vec<Tensor>,
    ) -> Self {
        let n_keys = init.len();
        let rank = collective.rank();
        let n_ranks = collective.n_ranks();
        AllreduceAggregator {
            driver: Driver::Serial(collective),
            rank,
            n_ranks,
            optimizer,
            velocity: (0..n_keys).map(|_| None).collect(),
            codec,
            topk: BTreeMap::new(),
            sr_rng: Rng::new(0xC0DE_C5EE_D000_0000 ^ (rank as u64 + 1)),
            init: Some(init),
            buckets: Vec::new(),
        }
    }

    /// Build the overlapped committer: partition keys into fixed-byte
    /// buckets and hand the collective to a dedicated comms thread.
    /// Results are bit-identical to [`AllreduceAggregator::new`] —
    /// only the schedule changes.
    #[cfg(feature = "overlap-commit")]
    pub fn with_overlap(
        mut collective: Collective,
        optimizer: Optimizer,
        codec: CodecKind,
        init: Vec<Tensor>,
        bucket_bytes: usize,
    ) -> Self {
        let shapes: Vec<Vec<usize>> = init.iter().map(|t| t.shape().to_vec()).collect();
        let buckets = partition_buckets(&shapes, bucket_bytes);
        let n_keys = init.len();
        let rank = collective.rank();
        let n_ranks = collective.n_ranks();
        collective.set_inflight_buckets(buckets.len());
        AllreduceAggregator {
            driver: Driver::Overlap(overlap::CommitPipe::spawn(collective)),
            rank,
            n_ranks,
            optimizer,
            velocity: (0..n_keys).map(|_| None).collect(),
            codec,
            topk: BTreeMap::new(),
            sr_rng: Rng::new(0xC0DE_C5EE_D000_0000 ^ (rank as u64 + 1)),
            init: Some(init),
            buckets,
        }
    }

    /// Without the `overlap-commit` feature the committer stays
    /// serial — same bytes, no comms thread.
    #[cfg(not(feature = "overlap-commit"))]
    pub fn with_overlap(
        collective: Collective,
        optimizer: Optimizer,
        codec: CodecKind,
        init: Vec<Tensor>,
        bucket_bytes: usize,
    ) -> Self {
        let _ = bucket_bytes;
        Self::new(collective, optimizer, codec, init)
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The key buckets the overlapped committer ships, in send order
    /// (empty for the serial committer).
    pub fn buckets(&self) -> &[Vec<usize>] {
        &self.buckets
    }

    /// Overlap accounting: `(blocked_s, comm_s)` — seconds the worker
    /// spent stalled in `wait_all` vs seconds the comms thread spent
    /// inside collectives. `blocked/comm` is the fraction of
    /// communication NOT hidden behind compute (1.0 = no overlap, →0 =
    /// fully hidden). Zeros for the serial committer.
    pub fn overlap_stats(&self) -> (f64, f64) {
        match &self.driver {
            Driver::Serial(_) => (0.0, 0.0),
            #[cfg(feature = "overlap-commit")]
            Driver::Overlap(p) => (p.blocked_s(), p.comm_s()),
        }
    }

    fn contribution(&mut self, key: u32, g: &Tensor) -> Contrib {
        compress_one(self.codec, &mut self.topk, &mut self.sr_rng, key, g)
    }

    /// Scale-then-apply one key's allreduced sum, byte-for-byte the PS
    /// barrier release (`apply_mean` -> `apply_grad`). All optimizer
    /// state is per-key, so the order buckets land in cannot change a
    /// single byte of the result.
    fn apply_key(&mut self, params: &mut [Tensor], k: usize, mut sum: Tensor) {
        sum.scale(1.0 / self.n_ranks as f32);
        match self.optimizer {
            Optimizer::Sgd { lr } => params[k].axpy(-lr, &sum),
            Optimizer::Momentum { lr, mu } => {
                let v = self.velocity[k].get_or_insert_with(|| Tensor::zeros(sum.shape()));
                v.scale(mu);
                v.axpy(1.0, &sum);
                params[k].axpy(-lr, v);
            }
        }
    }
}

impl GradAggregator for AllreduceAggregator {
    fn refresh(&mut self, params: &mut Vec<Tensor>) -> Result<(), String> {
        // Parameters live rank-local; only the first refresh installs
        // them (commit keeps them current thereafter).
        if let Some(init) = self.init.take() {
            *params = init;
        }
        if params.is_empty() {
            return Err("allreduce aggregator has no parameters".into());
        }
        Ok(())
    }

    fn commit(
        &mut self,
        step: u64,
        params: &mut Vec<Tensor>,
        grads: &[Tensor],
    ) -> Result<(), String> {
        #[cfg(feature = "overlap-commit")]
        if matches!(self.driver, Driver::Overlap(_)) {
            self.start_commit(step, params, grads)?;
            return self.wait_all(params);
        }
        if grads.len() != params.len() {
            return Err(format!("{} grads for {} params", grads.len(), params.len()));
        }
        let contribs: Vec<Contrib> =
            grads.iter().enumerate().map(|(k, g)| self.contribution(k as u32, g)).collect();
        let sums = match &mut self.driver {
            Driver::Serial(c) => c.allreduce_sum(step, contribs)?,
            #[cfg(feature = "overlap-commit")]
            Driver::Overlap(_) => unreachable!("overlapped commit handled above"),
        };
        for (k, sum) in sums.into_iter().enumerate() {
            self.apply_key(params, k, sum);
        }
        Ok(())
    }

    fn start_commit(
        &mut self,
        step: u64,
        params: &mut Vec<Tensor>,
        grads: &[Tensor],
    ) -> Result<(), String> {
        if grads.len() != params.len() {
            return Err(format!("{} grads for {} params", grads.len(), params.len()));
        }
        #[cfg(feature = "overlap-commit")]
        {
            let AllreduceAggregator { driver, buckets, codec, topk, sr_rng, .. } = self;
            if let Driver::Overlap(pipe) = driver {
                // Compress bucket-by-bucket and enqueue each one as
                // soon as it is ready: bucket i's collective streams
                // on the comms thread while bucket i+1 is still being
                // folded here. Tags carry (step, bucket) so any
                // cross-rank desync is a clean decode error.
                for (b, keys) in buckets.iter().enumerate() {
                    let contribs: Vec<Contrib> = keys
                        .iter()
                        .map(|&k| compress_one(*codec, topk, sr_rng, k as u32, &grads[k]))
                        .collect();
                    pipe.send(overlap::Job {
                        tag: (step << 16) | b as u64,
                        keys: keys.clone(),
                        contribs,
                    })?;
                }
                return Ok(());
            }
        }
        self.commit(step, params, grads)
    }

    fn wait_all(&mut self, params: &mut Vec<Tensor>) -> Result<(), String> {
        #[cfg(feature = "overlap-commit")]
        if let Driver::Overlap(pipe) = &mut self.driver {
            let drained = pipe.drain()?;
            for (keys, sums) in drained {
                for (&k, sum) in keys.iter().zip(sums) {
                    self.apply_key(params, k, sum);
                }
            }
            return Ok(());
        }
        let _ = params;
        Ok(())
    }

    fn push_wire_bytes(&self) -> u64 {
        match &self.driver {
            Driver::Serial(c) => c.reduce_wire_bytes(),
            #[cfg(feature = "overlap-commit")]
            Driver::Overlap(p) => p.reduce_bytes(),
        }
    }

    fn pull_wire_bytes(&self) -> u64 {
        match &self.driver {
            Driver::Serial(c) => c.bcast_wire_bytes(),
            #[cfg(feature = "overlap-commit")]
            Driver::Overlap(p) => p.bcast_bytes(),
        }
    }
}

/// Partition the key list into fixed-byte buckets,
/// **layer-order-reversed**: the bucket holding the highest-numbered
/// keys — the gradients backprop finishes first — ships first. Keys
/// inside a bucket stay ascending (the collective requires it); a
/// single key larger than the cap gets a bucket of its own.
pub fn partition_buckets(shapes: &[Vec<usize>], bucket_bytes: usize) -> Vec<Vec<usize>> {
    let cap = bucket_bytes.max(1);
    let mut buckets: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    let mut cur_bytes = 0usize;
    for k in (0..shapes.len()).rev() {
        let bytes = 4 * shapes[k].iter().product::<usize>();
        if !cur.is_empty() && cur_bytes + bytes > cap {
            cur.sort_unstable();
            buckets.push(std::mem::take(&mut cur));
            cur_bytes = 0;
        }
        cur.push(k);
        cur_bytes += bytes;
    }
    if !cur.is_empty() {
        cur.sort_unstable();
        buckets.push(cur);
    }
    buckets
}

/// One key's codec transform — the exact arithmetic and per-key state
/// (`PsClient`-identical) whether called from the serial committer or
/// the bucketized one. NOTE: `Quant8Sr` draws from a single sequential
/// RNG stream, so it alone is sensitive to key *order*; the bucketized
/// committer compresses in reversed-bucket order and therefore only
/// pins bitwise overlap parity for `none`/`quant8`/`topk`.
fn compress_one(
    codec: CodecKind,
    topk: &mut BTreeMap<u32, TopK>,
    sr_rng: &mut Rng,
    key: u32,
    g: &Tensor,
) -> Contrib {
    match codec {
        CodecKind::None => Contrib::Dense(g.clone()),
        CodecKind::TopK { fraction } => {
            let c = topk.entry(key).or_insert_with(|| TopK::new(fraction, g.len())).compress(g);
            Contrib::Comp(c)
        }
        CodecKind::Quant8 => Contrib::Comp(quantize8(g, None)),
        CodecKind::Quant8Sr => Contrib::Comp(quantize8(g, Some(sr_rng))),
    }
}

/// The dedicated comms thread behind the overlapped allreduce
/// committer: a job queue of (tag, keys, contributions) buckets and a
/// reply queue of summed tensors. The worker thread never touches the
/// wire; the comms thread never touches parameters.
#[cfg(feature = "overlap-commit")]
mod overlap {
    use std::sync::mpsc::{channel, Receiver, Sender};
    use std::thread::JoinHandle;
    use std::time::Instant;

    use crate::net::collective::{Collective, Contrib};
    use crate::tensor::Tensor;

    /// One bucket's collective, queued to the comms thread.
    pub struct Job {
        pub tag: u64,
        pub keys: Vec<usize>,
        pub contribs: Vec<Contrib>,
    }

    struct Reply {
        keys: Vec<usize>,
        sums: Result<Vec<Tensor>, String>,
        comm_s: f64,
        reduce_bytes: u64,
        bcast_bytes: u64,
    }

    pub struct CommitPipe {
        tx: Option<Sender<Job>>,
        rx: Receiver<Reply>,
        handle: Option<JoinHandle<()>>,
        in_flight: usize,
        blocked_s: f64,
        comm_s: f64,
        reduce_bytes: u64,
        bcast_bytes: u64,
    }

    impl CommitPipe {
        pub fn spawn(mut collective: Collective) -> Self {
            let (jtx, jrx) = channel::<Job>();
            let (rtx, rrx) = channel::<Reply>();
            let handle = std::thread::Builder::new()
                .name("allreduce-comms".into())
                .spawn(move || {
                    while let Ok(job) = jrx.recv() {
                        let t0 = Instant::now();
                        let sums = collective.allreduce_sum_keys(job.tag, &job.keys, job.contribs);
                        let reply = Reply {
                            keys: job.keys,
                            sums,
                            comm_s: t0.elapsed().as_secs_f64(),
                            reduce_bytes: collective.reduce_wire_bytes(),
                            bcast_bytes: collective.bcast_wire_bytes(),
                        };
                        if rtx.send(reply).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn allreduce comms thread");
            CommitPipe {
                tx: Some(jtx),
                rx: rrx,
                handle: Some(handle),
                in_flight: 0,
                blocked_s: 0.0,
                comm_s: 0.0,
                reduce_bytes: 0,
                bcast_bytes: 0,
            }
        }

        pub fn send(&mut self, job: Job) -> Result<(), String> {
            self.tx
                .as_ref()
                .expect("commit pipe closed")
                .send(job)
                .map_err(|_| "allreduce comms thread died".to_string())?;
            self.in_flight += 1;
            Ok(())
        }

        /// Collect every in-flight bucket's reply. All-or-nothing: on
        /// any failure the remaining replies are still consumed and
        /// the first error is returned with NO sums handed back —
        /// parameters stay at the last committed step, so a group
        /// reform replays the step exactly once, never applying a
        /// bucket twice.
        pub fn drain(&mut self) -> Result<Vec<(Vec<usize>, Vec<Tensor>)>, String> {
            let t0 = Instant::now();
            let mut out = Vec::with_capacity(self.in_flight);
            let mut first_err: Option<String> = None;
            while self.in_flight > 0 {
                let reply =
                    self.rx.recv().map_err(|_| "allreduce comms thread died".to_string())?;
                self.in_flight -= 1;
                self.comm_s += reply.comm_s;
                self.reduce_bytes = reply.reduce_bytes;
                self.bcast_bytes = reply.bcast_bytes;
                match reply.sums {
                    Ok(sums) => out.push((reply.keys, sums)),
                    Err(e) => first_err = first_err.or(Some(e)),
                }
            }
            self.blocked_s += t0.elapsed().as_secs_f64();
            match first_err {
                None => Ok(out),
                Some(e) => Err(e),
            }
        }

        pub fn reduce_bytes(&self) -> u64 {
            self.reduce_bytes
        }

        pub fn bcast_bytes(&self) -> u64 {
            self.bcast_bytes
        }

        pub fn blocked_s(&self) -> f64 {
            self.blocked_s
        }

        pub fn comm_s(&self) -> f64 {
            self.comm_s
        }
    }

    impl Drop for CommitPipe {
        fn drop(&mut self) {
            // Closing the job channel ends the comms loop. Every
            // collective wait is deadline-bounded, so the join is too.
            self.tx.take();
            if let Some(h) = self.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::collective::{inproc_mesh, Topology};

    fn quad_grad(params: &[Tensor], targets: &[Tensor]) -> Vec<Tensor> {
        // d/dw ||w - t||^2 = 2 (w - t) — batch-independent, so every
        // rank contributes identical gradients in lockstep.
        params
            .iter()
            .zip(targets)
            .map(|(w, t)| {
                let mut g = w.clone();
                g.axpy(-1.0, t);
                g.scale(2.0);
                g
            })
            .collect()
    }

    fn targets() -> Vec<Tensor> {
        vec![Tensor::from_vec(&[3], vec![1.0, -2.0, 0.5]), Tensor::from_vec(&[2], vec![4.0, 0.0])]
    }

    fn init() -> Vec<Tensor> {
        vec![Tensor::zeros(&[3]), Tensor::zeros(&[2])]
    }

    fn run_rank(
        mut agg: AllreduceAggregator,
        steps: u64,
        split: bool,
    ) -> Result<Vec<Tensor>, String> {
        let t = targets();
        let mut params = Vec::new();
        agg.refresh(&mut params)?;
        for step in 0..steps {
            let grads = quad_grad(&params, &t);
            if split {
                // The pipeline's overlap schedule: launch, then drain
                // where the next step's compute would run.
                agg.start_commit(step, &mut params, &grads)?;
                agg.wait_all(&mut params)?;
            } else {
                agg.commit(step, &mut params, &grads)?;
            }
        }
        Ok(params)
    }

    fn run_group(
        n: usize,
        topology: Topology,
        codec: CodecKind,
        opt: Optimizer,
        bucket_bytes: Option<usize>,
        split: bool,
    ) -> Vec<Vec<Tensor>> {
        let shapes: Vec<Vec<usize>> = init().iter().map(|t| t.shape().to_vec()).collect();
        let mesh = inproc_mesh(n);
        let mut out = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, links)| {
                    let shapes = shapes.clone();
                    s.spawn(move || {
                        let c = Collective::new(rank, n, links, topology, shapes).unwrap();
                        let agg = match bucket_bytes {
                            None => AllreduceAggregator::new(c, opt, codec, init()),
                            Some(bb) => {
                                AllreduceAggregator::with_overlap(c, opt, codec, init(), bb)
                            }
                        };
                        run_rank(agg, 6, split).unwrap()
                    })
                })
                .collect();
            for h in handles {
                out.push(h.join().unwrap());
            }
        });
        out
    }

    /// Serial reference replicating the backend arithmetic exactly:
    /// fold `n` identical contributions left-associated, scale by
    /// `1/n`, apply — the same ops the PS sync release performs.
    fn serial_ref(n: usize, lr: f32, steps: u64) -> Vec<Tensor> {
        let t = targets();
        let mut params = init();
        for _ in 0..steps {
            let grads = quad_grad(&params, &t);
            for (w, g) in params.iter_mut().zip(&grads) {
                let mut sum = g.clone();
                for _ in 1..n {
                    sum.axpy(1.0, g);
                }
                sum.scale(1.0 / n as f32);
                w.axpy(-lr, &sum);
            }
        }
        params
    }

    #[test]
    fn dense_ring_matches_serial_ref_bitwise() {
        let results =
            run_group(3, Topology::Ring, CodecKind::None, Optimizer::Sgd { lr: 0.1 }, None, false);
        let want = serial_ref(3, 0.1, 6);
        for got in &results {
            assert_eq!(got, &want);
        }
    }

    #[test]
    fn tree_ranks_stay_bit_identical_under_quant8() {
        let opt = Optimizer::Sgd { lr: 0.05 };
        let results = run_group(4, Topology::Tree, CodecKind::Quant8, opt, None, false);
        for got in &results[1..] {
            assert_eq!(got, &results[0]);
        }
    }

    #[test]
    fn momentum_ranks_stay_bit_identical() {
        let results = run_group(
            2,
            Topology::Ring,
            CodecKind::None,
            Optimizer::Momentum { lr: 0.05, mu: 0.9 },
            None,
            false,
        );
        assert_eq!(results[0], results[1]);
        // And momentum actually moved things (velocity state engaged).
        assert!(results[0][0].l2_norm() > 0.0);
    }

    #[test]
    fn overlap_matches_serial_bitwise() {
        // 8-byte cap: key 1 (2 floats) fills one bucket, key 0 (3
        // floats) the next — two buckets in flight per step, reversed
        // layer order. Final params must equal the serial committer's
        // byte-for-byte, on both topologies and with the split
        // schedule the pipeline actually runs.
        let opt = Optimizer::Sgd { lr: 0.1 };
        for topology in [Topology::Ring, Topology::Tree, Topology::Hd] {
            let want = run_group(3, topology, CodecKind::None, opt, None, false);
            for split in [false, true] {
                let got = run_group(3, topology, CodecKind::None, opt, Some(8), split);
                assert_eq!(got, want, "{topology:?} split={split}");
            }
        }
    }

    #[test]
    fn overlap_matches_serial_under_momentum_and_quant8() {
        let opt = Optimizer::Momentum { lr: 0.05, mu: 0.9 };
        let want = run_group(2, Topology::Ring, CodecKind::Quant8, opt, None, false);
        let got = run_group(2, Topology::Ring, CodecKind::Quant8, opt, Some(8), true);
        assert_eq!(got, want);
    }

    #[test]
    fn overlap_reports_stats_and_buckets() {
        let shapes: Vec<Vec<usize>> = init().iter().map(|t| t.shape().to_vec()).collect();
        let c = Collective::new(0, 1, vec![None], Topology::Ring, shapes).unwrap();
        let mut agg = AllreduceAggregator::with_overlap(
            c,
            Optimizer::Sgd { lr: 0.1 },
            CodecKind::None,
            init(),
            8,
        );
        if cfg!(feature = "overlap-commit") {
            assert_eq!(agg.buckets(), &[vec![1], vec![0]], "reversed layer order");
        } else {
            assert!(agg.buckets().is_empty());
        }
        let mut params = Vec::new();
        agg.refresh(&mut params).unwrap();
        let grads = quad_grad(&params, &targets());
        agg.start_commit(0, &mut params, &grads).unwrap();
        agg.wait_all(&mut params).unwrap();
        let (blocked, comm) = agg.overlap_stats();
        assert!(blocked >= 0.0 && comm >= 0.0);
    }

    #[test]
    fn partition_buckets_reverses_and_packs() {
        let shapes: Vec<Vec<usize>> = vec![vec![4], vec![2], vec![2], vec![10]];
        // 16-byte cap: reversed walk sees 40, 8, 8, 16 bytes.
        let buckets = partition_buckets(&shapes, 16);
        assert_eq!(buckets, vec![vec![3], vec![1, 2], vec![0]]);
        // Oversized key 3 (40 bytes) still got exactly one bucket, and
        // every key appears exactly once.
        let mut all: Vec<usize> = buckets.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3]);
        // Degenerate cap: one key per bucket, reversed.
        let tiny = partition_buckets(&shapes, 1);
        assert_eq!(tiny, vec![vec![3], vec![2], vec![1], vec![0]]);
    }
}
