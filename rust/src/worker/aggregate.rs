//! Backend-agnostic gradient aggregation for the worker loop.
//!
//! `worker::pipeline::run_agg_worker` drives training against any
//! [`GradAggregator`]: the parameter-server backend
//! ([`PsAggregator`], a thin wrapper over [`PsClient`]) or the
//! peer-to-peer collective backend ([`AllreduceAggregator`], over
//! [`net::collective`](crate::net::collective)). The worker loop itself
//! — prefetching loader, profiler, progress counter — does not know
//! which backend it is talking to; `train-dist --backend ps|allreduce`
//! picks the implementation.
//!
//! # Parity contract
//!
//! The allreduce backend reproduces the PS sync arithmetic exactly:
//! contributions are compressed with the same per-key codec state a
//! `PsClient` would use (top-k error feedback, the same
//! stochastic-rounding RNG stream per worker id), folded flat in rank
//! order with the PS fold's `axpy(1.0)`/`scatter_axpy(1.0)` adds,
//! scaled by `1/N` like the barrier release, and applied through the
//! same [`Optimizer`] update the shard store runs. With identical
//! seeds, sync PS and allreduce converge to byte-comparable losses —
//! pinned by the backend-parity integration tests.

use std::collections::BTreeMap;

use crate::net::collective::{Collective, Contrib};
use crate::ps::client::PsClient;
use crate::ps::compress::{quantize8, CodecKind, TopK};
use crate::ps::shard::Optimizer;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One step's worth of gradient aggregation, from the worker loop's
/// point of view: refresh parameters before compute, commit gradients
/// after. `commit` must not return until the step is durable for its
/// backend (push acked + barrier passed for PS; collective complete
/// and applied for allreduce).
pub trait GradAggregator {
    /// Refill `params` with the parameters to compute against this
    /// step (in-place; implementations reuse the buffer).
    fn refresh(&mut self, params: &mut Vec<Tensor>) -> Result<(), String>;

    /// Commit one step's gradients. Allreduce backends update `params`
    /// in place (every rank applies the identical mean); the PS
    /// backend leaves them to the next `refresh`.
    fn commit(
        &mut self,
        step: u64,
        params: &mut Vec<Tensor>,
        grads: &[Tensor],
    ) -> Result<(), String>;

    /// Cumulative gradient-direction wire bytes sent by this worker.
    fn push_wire_bytes(&self) -> u64;

    /// Cumulative parameter-direction wire bytes for this worker.
    fn pull_wire_bytes(&self) -> u64;
}

/// The parameter-server backend: pull from the fleet, push to it,
/// barrier in sync mode. Pure delegation — codec staging, retries,
/// reconnects and epoch fencing all live in [`PsClient`].
pub struct PsAggregator<'a> {
    client: &'a mut PsClient,
    sync: bool,
}

impl<'a> PsAggregator<'a> {
    pub fn new(client: &'a mut PsClient, sync: bool) -> Self {
        PsAggregator { client, sync }
    }
}

impl GradAggregator for PsAggregator<'_> {
    fn refresh(&mut self, params: &mut Vec<Tensor>) -> Result<(), String> {
        self.client.pull_all_into(params)
    }

    fn commit(
        &mut self,
        step: u64,
        _params: &mut Vec<Tensor>,
        grads: &[Tensor],
    ) -> Result<(), String> {
        self.client.push(step, grads)?;
        if self.sync {
            self.client.barrier(step)?;
        }
        Ok(())
    }

    fn push_wire_bytes(&self) -> u64 {
        self.client.push_wire_bytes()
    }

    fn pull_wire_bytes(&self) -> u64 {
        self.client.pull_wire_bytes()
    }
}

/// The collective backend: every rank holds the full model, allreduces
/// its (optionally compressed) gradient each step and applies the
/// identical mean locally through the same [`Optimizer`] arithmetic the
/// PS shard store uses. Inherently synchronous — the collective *is*
/// the barrier.
pub struct AllreduceAggregator {
    collective: Collective,
    optimizer: Optimizer,
    /// Per-key momentum state, lazily created like the shard store's
    /// velocity map — identical update order, identical bytes.
    velocity: Vec<Option<Tensor>>,
    codec: CodecKind,
    /// Per-key top-k compressors (error-feedback residuals), exactly
    /// the per-key state `PsClient::push` keeps.
    topk: BTreeMap<u32, TopK>,
    /// Stochastic-rounding stream for `quant8sr`, seeded per rank the
    /// same way `PsClient` seeds per worker id — same worker, same
    /// gradient, same bytes on either backend.
    sr_rng: Rng,
    /// Initial parameters, handed to the loop's buffer on the first
    /// `refresh`. All ranks must be constructed with identical init.
    init: Option<Vec<Tensor>>,
}

impl AllreduceAggregator {
    pub fn new(
        collective: Collective,
        optimizer: Optimizer,
        codec: CodecKind,
        init: Vec<Tensor>,
    ) -> Self {
        let n_keys = init.len();
        let rank = collective.rank() as u64;
        AllreduceAggregator {
            collective,
            optimizer,
            velocity: (0..n_keys).map(|_| None).collect(),
            codec,
            topk: BTreeMap::new(),
            sr_rng: Rng::new(0xC0DE_C5EE_D000_0000 ^ (rank + 1)),
            init: Some(init),
        }
    }

    pub fn rank(&self) -> usize {
        self.collective.rank()
    }

    fn contribution(&mut self, key: u32, g: &Tensor) -> Contrib {
        match self.codec {
            CodecKind::None => Contrib::Dense(g.clone()),
            CodecKind::TopK { fraction } => {
                let c = self
                    .topk
                    .entry(key)
                    .or_insert_with(|| TopK::new(fraction, g.len()))
                    .compress(g);
                Contrib::Comp(c)
            }
            CodecKind::Quant8 => Contrib::Comp(quantize8(g, None)),
            CodecKind::Quant8Sr => Contrib::Comp(quantize8(g, Some(&mut self.sr_rng))),
        }
    }
}

impl GradAggregator for AllreduceAggregator {
    fn refresh(&mut self, params: &mut Vec<Tensor>) -> Result<(), String> {
        // Parameters live rank-local; only the first refresh installs
        // them (commit keeps them current thereafter).
        if let Some(init) = self.init.take() {
            *params = init;
        }
        if params.is_empty() {
            return Err("allreduce aggregator has no parameters".into());
        }
        Ok(())
    }

    fn commit(
        &mut self,
        step: u64,
        params: &mut Vec<Tensor>,
        grads: &[Tensor],
    ) -> Result<(), String> {
        if grads.len() != params.len() {
            return Err(format!("{} grads for {} params", grads.len(), params.len()));
        }
        let contribs: Vec<Contrib> =
            grads.iter().enumerate().map(|(k, g)| self.contribution(k as u32, g)).collect();
        let sums = self.collective.allreduce_sum(step, contribs)?;
        let n = self.collective.n_ranks() as f32;
        for (k, mut sum) in sums.into_iter().enumerate() {
            // Scale-then-apply, byte-for-byte the PS barrier release
            // (`apply_mean` -> `apply_grad`).
            sum.scale(1.0 / n);
            match self.optimizer {
                Optimizer::Sgd { lr } => params[k].axpy(-lr, &sum),
                Optimizer::Momentum { lr, mu } => {
                    let v = self.velocity[k].get_or_insert_with(|| Tensor::zeros(sum.shape()));
                    v.scale(mu);
                    v.axpy(1.0, &sum);
                    params[k].axpy(-lr, v);
                }
            }
        }
        Ok(())
    }

    fn push_wire_bytes(&self) -> u64 {
        self.collective.reduce_wire_bytes()
    }

    fn pull_wire_bytes(&self) -> u64 {
        self.collective.bcast_wire_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::collective::{inproc_mesh, Topology};

    fn quad_grad(params: &[Tensor], targets: &[Tensor]) -> Vec<Tensor> {
        // d/dw ||w - t||^2 = 2 (w - t) — batch-independent, so every
        // rank contributes identical gradients in lockstep.
        params
            .iter()
            .zip(targets)
            .map(|(w, t)| {
                let mut g = w.clone();
                g.axpy(-1.0, t);
                g.scale(2.0);
                g
            })
            .collect()
    }

    fn targets() -> Vec<Tensor> {
        vec![Tensor::from_vec(&[3], vec![1.0, -2.0, 0.5]), Tensor::from_vec(&[2], vec![4.0, 0.0])]
    }

    fn init() -> Vec<Tensor> {
        vec![Tensor::zeros(&[3]), Tensor::zeros(&[2])]
    }

    fn run_rank(
        mut agg: AllreduceAggregator,
        steps: u64,
    ) -> Result<Vec<Tensor>, String> {
        let t = targets();
        let mut params = Vec::new();
        agg.refresh(&mut params)?;
        for step in 0..steps {
            let grads = quad_grad(&params, &t);
            agg.commit(step, &mut params, &grads)?;
        }
        Ok(params)
    }

    fn run_group(n: usize, topology: Topology, codec: CodecKind, opt: Optimizer) -> Vec<Vec<Tensor>> {
        let shapes: Vec<Vec<usize>> = init().iter().map(|t| t.shape().to_vec()).collect();
        let mesh = inproc_mesh(n);
        let mut out = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, links)| {
                    let shapes = shapes.clone();
                    s.spawn(move || {
                        let c = Collective::new(rank, n, links, topology, shapes).unwrap();
                        run_rank(AllreduceAggregator::new(c, opt, codec, init()), 6).unwrap()
                    })
                })
                .collect();
            for h in handles {
                out.push(h.join().unwrap());
            }
        });
        out
    }

    /// Serial reference replicating the backend arithmetic exactly:
    /// fold `n` identical contributions left-associated, scale by
    /// `1/n`, apply — the same ops the PS sync release performs.
    fn serial_ref(n: usize, lr: f32, steps: u64) -> Vec<Tensor> {
        let t = targets();
        let mut params = init();
        for _ in 0..steps {
            let grads = quad_grad(&params, &t);
            for (w, g) in params.iter_mut().zip(&grads) {
                let mut sum = g.clone();
                for _ in 1..n {
                    sum.axpy(1.0, g);
                }
                sum.scale(1.0 / n as f32);
                w.axpy(-lr, &sum);
            }
        }
        params
    }

    #[test]
    fn dense_ring_matches_serial_ref_bitwise() {
        let results = run_group(3, Topology::Ring, CodecKind::None, Optimizer::Sgd { lr: 0.1 });
        let want = serial_ref(3, 0.1, 6);
        for got in &results {
            assert_eq!(got, &want);
        }
    }

    #[test]
    fn tree_ranks_stay_bit_identical_under_quant8() {
        let results =
            run_group(4, Topology::Tree, CodecKind::Quant8, Optimizer::Sgd { lr: 0.05 });
        for got in &results[1..] {
            assert_eq!(got, &results[0]);
        }
    }

    #[test]
    fn momentum_ranks_stay_bit_identical() {
        let results = run_group(
            2,
            Topology::Ring,
            CodecKind::None,
            Optimizer::Momentum { lr: 0.05, mu: 0.9 },
        );
        assert_eq!(results[0], results[1]);
        // And momentum actually moved things (velocity state engaged).
        assert!(results[0][0].l2_norm() > 0.0);
    }
}
