//! The worker's mini-batch loop: Fig. 1's seven steps wired to the PJRT
//! runtime, the prefetching loader and (in distributed mode) the
//! parameter-server client.
//!
//! Step accounting notes:
//! * Steps 2–3 (load+prep) run in the loader's background thread; the
//!   profiler records the *exposed* wait, which is what overhead means
//!   under pipelining.
//! * Steps 4–6 execute inside one fused PJRT call on CPU (H2D is a
//!   no-op, the update is fused into the train_step artifact); their
//!   cost is attributed to Compute, and H2d/Update record the literal
//!   build/readback that brackets the call.

use crate::data::loader::{Batch, PrefetchLoader};
use crate::ps::client::PsClient;
use crate::ps::compress::{CodecKind, PullCodec};
use crate::runtime::exec::TrainExecutable;
use crate::tensor::Tensor;
use crate::worker::aggregate::{GradAggregator, PsAggregator};
use crate::worker::profiler::{Step, StepProfiler};

/// Knobs for a worker run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub lr: f32,
    pub steps: usize,
    /// First step to run (restart-from-checkpoint resumes here; the
    /// worker executes steps `start_step..steps`). Local runs ignore it.
    pub start_step: usize,
    /// Loader queue depth; 0 disables pipelining (ablation mode — the
    /// paper's "low throughput of feeding training data" bottleneck).
    pub prefetch_depth: usize,
    pub log_every: usize,
    /// Gradient codec for distributed pushes (§1.1.1 traffic saver;
    /// ignored by local runs, which never touch a parameter server).
    pub codec: CodecKind,
    /// Parameter codec for distributed pulls — the other direction of
    /// Lemma 3.2's traffic term (ignored by local runs).
    pub pull_codec: PullCodec,
    /// Fixed-byte gradient bucket size enabling the overlapped
    /// committer (`start_commit`/`wait_all`): this step's buckets
    /// stream while the next batch is prefetched and computed. `None`
    /// keeps the serial blocking commit.
    pub bucket_bytes: Option<usize>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            lr: 0.01,
            steps: 100,
            start_step: 0,
            prefetch_depth: 2,
            log_every: 0,
            codec: CodecKind::None,
            pull_codec: PullCodec::None,
            bucket_bytes: None,
        }
    }
}

/// Outcome of a worker run.
#[derive(Debug)]
pub struct WorkerStats {
    pub losses: Vec<f32>,
    pub profiler: StepProfiler,
    pub wall_s: f64,
    /// Samples processed per wall-clock second.
    pub throughput: f64,
    /// Encoded push-body bytes sent to parameter servers (0 for local
    /// runs) — the measured side of Lemma 3.2's traffic term.
    pub push_wire_bytes: u64,
    /// Pull-reply body bytes received from parameter servers (0 for
    /// local runs) — the pull-direction twin of `push_wire_bytes`.
    pub pull_wire_bytes: u64,
}

fn spawn_loader<F>(make: F, batch: usize, steps: usize, depth: usize) -> PrefetchLoader
where
    F: FnMut(u64, usize) -> Batch + Send + 'static,
{
    // depth 0 = synchronous-ish: a queue of 1 still prefetches one batch;
    // true unpipelined mode generates inline (see run_local_unpipelined).
    PrefetchLoader::spawn(make, 0, batch, steps, depth.max(1))
}

/// Single-node training with the fused `train_step` artifact (steps
/// 2–6; no parameter server).
pub fn run_local<F>(
    exe: &TrainExecutable,
    mut params: Vec<Tensor>,
    make_batch: F,
    cfg: &PipelineConfig,
) -> Result<(Vec<Tensor>, WorkerStats), String>
where
    F: FnMut(u64, usize) -> Batch + Send + 'static,
{
    let mut profiler = StepProfiler::new();
    let mut losses = Vec::with_capacity(cfg.steps);
    let t0 = std::time::Instant::now();
    let batch_size = exe.meta.batch;

    if cfg.prefetch_depth == 0 {
        // Ablation: generate the batch inline — load+prep fully exposed.
        let mut make_batch = make_batch;
        for step in 0..cfg.steps {
            let b = {
                let _t = profiler.time(Step::DataLoad);
                make_batch((step * batch_size) as u64, batch_size)
            };
            let out = {
                let _t = profiler.time(Step::Compute);
                exe.run(&params, &b, Some(cfg.lr))?
            };
            params = out.tensors;
            losses.push(out.loss);
            maybe_log(cfg, step, out.loss);
        }
    } else {
        let mut loader = spawn_loader(make_batch, batch_size, cfg.steps, cfg.prefetch_depth);
        for step in 0..cfg.steps {
            let b = {
                let _t = profiler.time(Step::DataLoad);
                loader.next().ok_or("loader exhausted early")?
            };
            let out = {
                let _t = profiler.time(Step::Compute);
                exe.run(&params, &b, Some(cfg.lr))?
            };
            params = out.tensors;
            losses.push(out.loss);
            maybe_log(cfg, step, out.loss);
        }
    }

    let wall_s = t0.elapsed().as_secs_f64();
    let throughput = (cfg.steps * batch_size) as f64 / wall_s;
    Ok((
        params,
        WorkerStats {
            losses,
            profiler,
            wall_s,
            throughput,
            push_wire_bytes: 0,
            pull_wire_bytes: 0,
        },
    ))
}

/// Distributed worker against the parameter-server backend: pull ->
/// grad_step -> push (steps 1–7), async or synchronous (barrier per
/// step). A thin wrapper over [`run_agg_worker`] with a
/// [`PsAggregator`] — signature and behavior unchanged from when this
/// was the only backend.
///
/// Runs steps `cfg.start_step..cfg.steps` (a restarted worker resumes
/// where its previous incarnation died). After each fully committed
/// step (push acked, barrier passed in sync mode) the optional
/// `progress` counter is advanced to `step + 1` — the supervisor reads
/// it to pick the resume point for a replacement worker.
pub fn run_ps_worker<F>(
    grad_exe: &TrainExecutable,
    client: &mut PsClient,
    make_batch: F,
    cfg: &PipelineConfig,
    sync: bool,
    progress: Option<&std::sync::atomic::AtomicUsize>,
) -> Result<WorkerStats, String>
where
    F: FnMut(u64, usize) -> Batch + Send + 'static,
{
    client.set_codec(cfg.codec);
    client.set_pull_codec(cfg.pull_codec);
    let mut agg = PsAggregator::new(client, sync);
    let mut params = Vec::new();
    run_agg_worker(grad_exe, &mut agg, &mut params, make_batch, cfg, progress)
}

/// Distributed worker loop over any aggregation backend. The loop owns
/// the loader, profiler and progress accounting; the
/// [`GradAggregator`] owns where gradients go (PS fleet or collective)
/// — `train-dist --backend` swaps the aggregator, not the loop.
///
/// `params` is the caller-owned parameter buffer: refilled by the
/// aggregator each refresh and left holding the last *committed* state
/// on both success and error — the allreduce coordinator reads it back
/// for reform adoption and the final report (the PS backend keeps
/// authoritative state on the servers and ignores it).
///
/// With `cfg.bucket_bytes` set (and the `overlap-commit` feature on)
/// the loop runs the overlapped schedule instead: step `s`'s gradients
/// are launched with `start_commit` and drained with `wait_all` at the
/// top of step `s+1` — the wire stays busy while the next batch is
/// prefetched and computed. The progress counter still advances only
/// after a step's commit is durable, and `wait_all`'s all-or-nothing
/// contract keeps `params` at the last committed step on error, so
/// restart/reform semantics are unchanged from the blocking schedule.
pub fn run_agg_worker<F, A>(
    grad_exe: &TrainExecutable,
    agg: &mut A,
    params: &mut Vec<Tensor>,
    make_batch: F,
    cfg: &PipelineConfig,
    progress: Option<&std::sync::atomic::AtomicUsize>,
) -> Result<WorkerStats, String>
where
    F: FnMut(u64, usize) -> Batch + Send + 'static,
    A: GradAggregator,
{
    let mut profiler = StepProfiler::new();
    let n_steps = cfg.steps.saturating_sub(cfg.start_step);
    let mut losses = Vec::with_capacity(n_steps);
    let t0 = std::time::Instant::now();
    let batch_size = grad_exe.meta.batch;
    let wire_bytes_before = agg.push_wire_bytes();
    let pull_bytes_before = agg.pull_wire_bytes();
    // The loader resumes at the restart step's sample offset, so a
    // restarted worker re-reads exactly the batches it has not yet
    // committed.
    let mut loader = PrefetchLoader::spawn(
        make_batch,
        (cfg.start_step * batch_size) as u64,
        batch_size,
        n_steps,
        cfg.prefetch_depth.max(1),
    );
    let overlap = cfg!(feature = "overlap-commit") && cfg.bucket_bytes.is_some();
    for step in cfg.start_step..cfg.steps {
        // In the overlapped schedule the batch is fetched *before*
        // draining the previous step's buckets, so any exposed
        // prefetch wait hides behind the in-flight communication.
        let mut early_batch = None;
        if overlap {
            {
                let _t = profiler.time(Step::DataLoad);
                early_batch = Some(loader.next().ok_or("loader exhausted early")?);
            }
            // Drain the previous step's in-flight buckets — their
            // collectives streamed while this batch was prefetched.
            // Only once they are durable does the previous step count
            // as committed.
            {
                let _t = profiler.time(Step::DistUpdate);
                agg.wait_all(params)?;
            }
            if let Some(p) = progress {
                if step > cfg.start_step {
                    p.store(step, std::sync::atomic::Ordering::SeqCst);
                }
            }
        }
        {
            let _t = profiler.time(Step::ParamRefresh);
            agg.refresh(params)?;
        }
        let b = match early_batch {
            Some(b) => b,
            None => {
                let _t = profiler.time(Step::DataLoad);
                loader.next().ok_or("loader exhausted early")?
            }
        };
        let out = {
            let _t = profiler.time(Step::Compute);
            grad_exe.run(params, &b, None)?
        };
        {
            let _t = profiler.time(Step::DistUpdate);
            if overlap {
                agg.start_commit(step as u64, params, &out.tensors)?;
            } else {
                agg.commit(step as u64, params, &out.tensors)?;
            }
        }
        if !overlap {
            if let Some(p) = progress {
                p.store(step + 1, std::sync::atomic::Ordering::SeqCst);
            }
        }
        losses.push(out.loss);
        maybe_log(cfg, step, out.loss);
    }
    if overlap && cfg.start_step < cfg.steps {
        {
            let _t = profiler.time(Step::DistUpdate);
            agg.wait_all(params)?;
        }
        if let Some(p) = progress {
            p.store(cfg.steps, std::sync::atomic::Ordering::SeqCst);
        }
    }

    let wall_s = t0.elapsed().as_secs_f64();
    let throughput = (n_steps * batch_size) as f64 / wall_s;
    Ok(WorkerStats {
        losses,
        profiler,
        wall_s,
        throughput,
        push_wire_bytes: agg.push_wire_bytes() - wire_bytes_before,
        pull_wire_bytes: agg.pull_wire_bytes() - pull_bytes_before,
    })
}

fn maybe_log(cfg: &PipelineConfig, step: usize, loss: f32) {
    if cfg.log_every > 0 && step % cfg.log_every == 0 {
        crate::info!("worker", "step", step = step, loss = format!("{loss:.4}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::ImageTask;
    use crate::runtime::exec::Runtime;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("index.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Runtime::new(&dir).unwrap())
    }

    fn batcher(seed: u64) -> impl FnMut(u64, usize) -> Batch + Send + 'static {
        let task = ImageTask::cifar_like(seed);
        move |start, n| {
            let (x, y) = task.batch(start, n);
            Batch { start, x_f32: x.into_vec(), x_i32: vec![], y_i32: y }
        }
    }

    #[test]
    fn local_pipeline_trains_and_profiles() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("cnn_gemm_b16_train").unwrap();
        let (_, params) = rt.family_init("cnn").unwrap();
        let cfg =
            PipelineConfig { lr: 0.02, steps: 8, prefetch_depth: 2, ..Default::default() };
        let (_, stats) = run_local(&exe, params, batcher(1), &cfg).unwrap();
        assert_eq!(stats.losses.len(), 8);
        assert_eq!(stats.profiler.iterations(), 8);
        // Fresh data each step, but 8 steps on a separable task should
        // already cut loss below the ln(10) start.
        assert!(stats.losses[7] < stats.losses[0]);
        // Pipelined loading should be nearly free vs compute.
        assert!(stats.profiler.r_o() < 0.5, "r_o={}", stats.profiler.r_o());
        assert!(stats.throughput > 0.0);
    }

    #[test]
    fn unpipelined_exposes_more_overhead() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("cnn_gemm_b16_train").unwrap();
        let (_, params) = rt.family_init("cnn").unwrap();
        let piped =
            PipelineConfig { lr: 0.02, steps: 6, prefetch_depth: 2, ..Default::default() };
        let unpiped = PipelineConfig { prefetch_depth: 0, ..piped.clone() };
        let (_, s1) = run_local(&exe, params.clone(), batcher(2), &piped).unwrap();
        let (_, s0) = run_local(&exe, params, batcher(2), &unpiped).unwrap();
        // Same losses (determinism) regardless of pipelining.
        for (a, b) in s1.losses.iter().zip(&s0.losses) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        // Unpipelined data wait must be >= pipelined exposed wait.
        assert!(
            s0.profiler.mean(Step::DataLoad) >= s1.profiler.mean(Step::DataLoad),
            "unpipelined {} < pipelined {}",
            s0.profiler.mean(Step::DataLoad),
            s1.profiler.mean(Step::DataLoad)
        );
    }
}
