//! Per-step timing for the Fig. 1 pipeline.
//!
//! §3.2: "a practitioner can quickly profile the training program for a
//! couple of epochs" to estimate `R_O` — this is that profiler. Step 5
//! (device compute) is the hideable-behind budget; every other step's
//! *exposed* time is overhead.

use std::time::Instant;

use crate::util::stats::Welford;

/// The paper's seven mini-batch steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    ParamRefresh = 0, // 1. pull W from parameter servers
    DataLoad = 1,     // 2. mini-batch from storage (exposed wait)
    DataPrep = 2,     // 3. decode/augment
    H2d = 3,          // 4. host -> device transfer
    Compute = 4,      // 5. device fwd/bwd
    Update = 5,       // 6. apply ΔW
    DistUpdate = 6,   // 7. push to parameter servers
}

pub const ALL_STEPS: [Step; 7] = [
    Step::ParamRefresh,
    Step::DataLoad,
    Step::DataPrep,
    Step::H2d,
    Step::Compute,
    Step::Update,
    Step::DistUpdate,
];

impl Step {
    pub fn name(&self) -> &'static str {
        match self {
            Step::ParamRefresh => "param_refresh",
            Step::DataLoad => "data_load",
            Step::DataPrep => "data_prep",
            Step::H2d => "h2d",
            Step::Compute => "compute",
            Step::Update => "update",
            Step::DistUpdate => "dist_update",
        }
    }
}

/// Accumulates per-step seconds across iterations.
#[derive(Debug, Default)]
pub struct StepProfiler {
    stats: [Welford; 7],
}

/// RAII timer for one step.
pub struct StepTimer<'a> {
    profiler: &'a mut StepProfiler,
    step: Step,
    t0: Instant,
}

impl StepProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn time(&mut self, step: Step) -> StepTimer<'_> {
        StepTimer { step, t0: Instant::now(), profiler: self }
    }

    pub fn record(&mut self, step: Step, seconds: f64) {
        self.stats[step as usize].push(seconds);
    }

    pub fn mean(&self, step: Step) -> f64 {
        self.stats[step as usize].mean()
    }

    pub fn iterations(&self) -> u64 {
        self.stats[Step::Compute as usize].count()
    }

    /// Mean compute seconds per iteration (T_C).
    pub fn t_c(&self) -> f64 {
        self.mean(Step::Compute)
    }

    /// Mean *exposed* overhead seconds per iteration (T_O): everything
    /// that is not step 5.
    pub fn t_o(&self) -> f64 {
        ALL_STEPS
            .iter()
            .filter(|s| **s != Step::Compute)
            .map(|s| self.mean(*s))
            .sum()
    }

    /// R_O = T_O / T_C — the Lemma 3.1 input.
    pub fn r_o(&self) -> f64 {
        let tc = self.t_c();
        if tc == 0.0 {
            0.0
        } else {
            self.t_o() / tc
        }
    }

    /// Human-readable per-step report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for step in ALL_STEPS {
            s.push_str(&format!(
                "{:14} {:9.3} ms\n",
                step.name(),
                self.mean(step) * 1e3
            ));
        }
        s.push_str(&format!(
            "T_C={:.3}ms T_O={:.3}ms R_O={:.3}\n",
            self.t_c() * 1e3,
            self.t_o() * 1e3,
            self.r_o()
        ));
        s
    }
}

impl Drop for StepTimer<'_> {
    fn drop(&mut self) {
        let dt = self.t0.elapsed().as_secs_f64();
        self.profiler.record(self.step, dt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r_o_arithmetic() {
        let mut p = StepProfiler::new();
        for _ in 0..10 {
            p.record(Step::Compute, 1.0);
            p.record(Step::DataLoad, 0.05);
            p.record(Step::DistUpdate, 0.05);
        }
        assert!((p.t_c() - 1.0).abs() < 1e-12);
        assert!((p.t_o() - 0.1).abs() < 1e-12);
        assert!((p.r_o() - 0.1).abs() < 1e-12);
        assert_eq!(p.iterations(), 10);
    }

    #[test]
    fn timer_records() {
        let mut p = StepProfiler::new();
        {
            let _t = p.time(Step::Compute);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(p.t_c() >= 0.004);
    }

    #[test]
    fn zero_compute_safe() {
        let p = StepProfiler::new();
        assert_eq!(p.r_o(), 0.0);
    }

    #[test]
    fn report_contains_all_steps() {
        let p = StepProfiler::new();
        let r = p.report();
        for s in ALL_STEPS {
            assert!(r.contains(s.name()));
        }
    }
}
