//! Learning-rate schedules (paper §1.1.1: "the settings of
//! hyper-parameters such as learning rate ... are crucial" [6, 17, 25]).
//!
//! Pure functions of the step index so every worker computes the same
//! rate without coordination — important in the async PS mode, where a
//! server-side schedule would race with in-flight pushes.

/// A learning-rate schedule.
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    /// Constant `lr`.
    Const { lr: f32 },
    /// Multiply by `gamma` every `every` steps (classic step decay).
    StepDecay { lr: f32, gamma: f32, every: usize },
    /// Linear warmup to `lr` over `warmup` steps, then cosine decay to
    /// `final_lr` at `total` steps.
    WarmupCosine { lr: f32, final_lr: f32, warmup: usize, total: usize },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            LrSchedule::Const { lr } => lr,
            LrSchedule::StepDecay { lr, gamma, every } => {
                assert!(every > 0);
                lr * gamma.powi((step / every) as i32)
            }
            LrSchedule::WarmupCosine { lr, final_lr, warmup, total } => {
                if warmup > 0 && step < warmup {
                    return lr * (step + 1) as f32 / warmup as f32;
                }
                let t = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
                let t = t.clamp(0.0, 1.0);
                final_lr + 0.5 * (lr - final_lr) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }

    /// Parse "const:0.01", "step:0.1,0.5,1000", "cosine:0.1,0.001,100,5000".
    pub fn parse(s: &str) -> Result<LrSchedule, String> {
        let (kind, rest) = s.split_once(':').ok_or("schedule needs kind:args")?;
        let parts: Vec<&str> = rest.split(',').collect();
        let f = |i: usize| -> Result<f32, String> {
            parts
                .get(i)
                .ok_or_else(|| format!("missing arg {i} in {s:?}"))?
                .parse()
                .map_err(|e| format!("bad number in {s:?}: {e}"))
        };
        let u = |i: usize| -> Result<usize, String> { Ok(f(i)? as usize) };
        match kind {
            "const" => Ok(LrSchedule::Const { lr: f(0)? }),
            "step" => Ok(LrSchedule::StepDecay { lr: f(0)?, gamma: f(1)?, every: u(2)? }),
            "cosine" => Ok(LrSchedule::WarmupCosine {
                lr: f(0)?,
                final_lr: f(1)?,
                warmup: u(2)?,
                total: u(3)?,
            }),
            other => Err(format!("unknown schedule kind {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        let s = LrSchedule::Const { lr: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(10_000), 0.1);
    }

    #[test]
    fn step_decay() {
        let s = LrSchedule::StepDecay { lr: 1.0, gamma: 0.5, every: 10 };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(9), 1.0);
        assert_eq!(s.at(10), 0.5);
        assert_eq!(s.at(25), 0.25);
    }

    #[test]
    fn warmup_then_cosine() {
        let s = LrSchedule::WarmupCosine { lr: 1.0, final_lr: 0.0, warmup: 10, total: 110 };
        assert!(s.at(0) < 0.2); // warming up
        assert!((s.at(9) - 1.0).abs() < 1e-6); // warmup done
        assert!((s.at(10) - 1.0).abs() < 1e-6); // cosine start
        let mid = s.at(60);
        assert!((mid - 0.5).abs() < 0.01); // halfway
        assert!(s.at(110) < 1e-6); // decayed out
                                   // monotone decreasing after warmup
        for step in 10..109 {
            assert!(s.at(step + 1) <= s.at(step) + 1e-6);
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(
            LrSchedule::parse("const:0.01").unwrap(),
            LrSchedule::Const { lr: 0.01 }
        );
        assert_eq!(
            LrSchedule::parse("step:0.1,0.5,1000").unwrap(),
            LrSchedule::StepDecay { lr: 0.1, gamma: 0.5, every: 1000 }
        );
        assert!(matches!(
            LrSchedule::parse("cosine:0.1,0.001,100,5000").unwrap(),
            LrSchedule::WarmupCosine { .. }
        ));
        assert!(LrSchedule::parse("exp:1").is_err());
        assert!(LrSchedule::parse("const").is_err());
        assert!(LrSchedule::parse("step:0.1").is_err());
    }
}
