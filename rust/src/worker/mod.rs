//! Worker runtime: the 7-step mini-batch pipeline of Fig. 1, with
//! per-step instrumentation that yields the `R_O` Lemma 3.1 consumes.

pub mod aggregate;
pub mod pipeline;
pub mod schedule;
pub mod trace;
pub mod profiler;

pub use aggregate::{AllreduceAggregator, GradAggregator, PsAggregator};
pub use pipeline::{PipelineConfig, WorkerStats};
pub use schedule::LrSchedule;
pub use trace::TraceRecorder;
pub use profiler::{Step, StepProfiler};
