//! Chrome-trace export of the 7-step pipeline (the paper's §3.2 advice:
//! "visualize the execution of a training task to derive R_O" — our
//! equivalent of the MXNet/TensorFlow timeline or nvprof).
//!
//! Records (step, start, duration) events per iteration and renders the
//! `chrome://tracing` / Perfetto JSON array format.

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

use super::profiler::Step;

/// One timed span.
#[derive(Debug, Clone)]
pub struct Span {
    pub step: Step,
    pub iteration: usize,
    /// Microseconds since trace start.
    pub start_us: u64,
    pub dur_us: u64,
}

/// Collects spans; thread-compatible (one recorder per worker).
#[derive(Debug)]
pub struct TraceRecorder {
    origin: Instant,
    pub worker_id: u32,
    spans: Vec<Span>,
}

impl TraceRecorder {
    pub fn new(worker_id: u32) -> Self {
        TraceRecorder { origin: Instant::now(), worker_id, spans: Vec::new() }
    }

    /// Time a closure as one span.
    pub fn record<T>(&mut self, step: Step, iteration: usize, f: impl FnOnce() -> T) -> T {
        let start = self.origin.elapsed();
        let out = f();
        let end = self.origin.elapsed();
        self.spans.push(Span {
            step,
            iteration,
            start_us: start.as_micros() as u64,
            dur_us: (end - start).as_micros() as u64,
        });
        out
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Render the Chrome trace-event JSON array.
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, s) in self.spans.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            let _ = write!(
                out,
                r#"{{"name":"{}","cat":"pipeline","ph":"X","ts":{},"dur":{},"pid":1,"tid":{},"args":{{"iteration":{}}}}}"#,
                s.step.name(),
                s.start_us,
                s.dur_us,
                self.worker_id,
                s.iteration
            );
        }
        out.push_str("\n]\n");
        out
    }

    pub fn save(&self, path: &Path) -> Result<(), String> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).map_err(|e| e.to_string())?;
        }
        std::fs::write(path, self.to_chrome_json()).map_err(|e| e.to_string())
    }

    /// Overlap fraction: how much of total data-step time was hidden
    /// behind compute (spans with identical iteration overlapping the
    /// compute span). Simplified: exposed = recorded wall; hidden is
    /// whatever the loader did off-thread, so this reports the ratio of
    /// compute time to total span time — the pipelining efficiency.
    pub fn compute_fraction(&self) -> f64 {
        let total: u64 = self.spans.iter().map(|s| s.dur_us).sum();
        if total == 0 {
            return 0.0;
        }
        let compute: u64 = self
            .spans
            .iter()
            .filter(|s| s.step == Step::Compute)
            .map(|s| s.dur_us)
            .sum();
        compute as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_spans_in_order() {
        let mut tr = TraceRecorder::new(3);
        tr.record(Step::DataLoad, 0, || std::thread::sleep(std::time::Duration::from_millis(2)));
        tr.record(Step::Compute, 0, || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert_eq!(tr.spans().len(), 2);
        assert!(tr.spans()[1].start_us >= tr.spans()[0].start_us + tr.spans()[0].dur_us);
        assert!(tr.compute_fraction() > 0.5);
    }

    #[test]
    fn chrome_json_is_valid() {
        let mut tr = TraceRecorder::new(1);
        tr.record(Step::Compute, 0, || {});
        tr.record(Step::DistUpdate, 0, || {});
        let json = tr.to_chrome_json();
        // Parse with the in-house JSON parser: must be a 2-element array
        // of objects with the right fields.
        let v = crate::util::json::Json::parse(&json).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].str_field("name").unwrap(), "compute");
        assert_eq!(arr[0].str_field("ph").unwrap(), "X");
        assert!(arr[1].get("args").unwrap().get("iteration").is_some());
    }

    #[test]
    fn empty_trace_safe() {
        let tr = TraceRecorder::new(0);
        assert_eq!(tr.compute_fraction(), 0.0);
        assert!(crate::util::json::Json::parse(&tr.to_chrome_json()).is_ok());
    }

    #[test]
    fn save_writes_file() {
        let mut tr = TraceRecorder::new(0);
        tr.record(Step::Compute, 0, || {});
        let mut p = std::env::temp_dir();
        p.push(format!("dtlsda_trace_{}.json", std::process::id()));
        tr.save(&p).unwrap();
        assert!(p.exists());
        std::fs::remove_file(&p).ok();
    }
}
