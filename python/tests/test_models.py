"""L2 model tests: shapes, gradients, step builders, and the artifact
calling conventions the rust runtime depends on."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.model import (
    Cnn,
    CnnConfig,
    TransformerLm,
    LmConfig,
    build_train_step,
    build_grad_step,
    build_eval_step,
    step_specs,
)
from compile.models.cnn import ConvSpec


def _params(model, seed=0):
    return [jnp.asarray(a) for a in model.init(seed)]


def _cnn_batch(rng, n=4, classes=10):
    x = jnp.asarray(rng.standard_normal((n, 32, 32, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, classes, (n,)), jnp.int32)
    return x, y


def _lm_batch(rng, model, n=2):
    cfg = model.cfg
    x = jnp.asarray(rng.integers(0, cfg.vocab, (n, cfg.seq)), jnp.int32)
    y = jnp.asarray(rng.integers(0, cfg.vocab, (n, cfg.seq)), jnp.int32)
    return x, y


# ------------------------------------------------------------------ CNN

def test_cnn_param_specs_order_and_count():
    cnn = Cnn()
    specs = cnn.param_specs()
    assert len(specs) == 10
    assert specs[0] == ("conv0.w", (5, 5, 3, 32))
    assert specs[-2] == ("head.w", (256, 10))
    total = sum(int(np.prod(s)) for _, s in specs)
    assert total == 654_666


def test_cnn_init_matches_specs():
    cnn = Cnn()
    init = cnn.init(0)
    for (name, shape), arr in zip(cnn.param_specs(), init):
        assert arr.shape == tuple(shape), name
        assert arr.dtype == np.float32
    # zero-init head => initial loss is exactly ln(classes)
    rng = np.random.default_rng(0)
    x, y = _cnn_batch(rng)
    loss = cnn.loss(_params(cnn), x, y)
    np.testing.assert_allclose(float(loss), np.log(10), rtol=1e-5)


def test_cnn_logits_shape():
    cnn = Cnn()
    rng = np.random.default_rng(1)
    x, _ = _cnn_batch(rng, n=3)
    logits = cnn.logits(_params(cnn), x)
    assert logits.shape == (3, 10)


def test_cnn_grads_nonzero_everywhere():
    # At the zero-head init only the head receives gradient (backprop
    # through a zero matrix); after one SGD step every layer must.
    cnn = Cnn()
    rng = np.random.default_rng(2)
    x, y = _cnn_batch(rng)
    p = _params(cnn)
    loss_fn = lambda ps: cnn.loss(ps, x, y)  # noqa: E731
    g0 = jax.grad(loss_fn)(p)
    names = [n for n, _ in cnn.param_specs()]
    assert float(jnp.linalg.norm(g0[names.index("head.w")])) > 0
    assert float(jnp.linalg.norm(g0[names.index("conv0.w")])) == 0.0
    p1 = [pi - 0.05 * gi for pi, gi in zip(p, g0)]
    g1 = jax.grad(loss_fn)(p1)
    for name, g in zip(names, g1):
        norm = float(jnp.linalg.norm(g))
        assert np.isfinite(norm), name
        if name.endswith(".w"):
            assert norm > 0, f"{name} grad is zero after one step"


def test_cnn_fft_and_gemm_same_loss():
    rng = np.random.default_rng(3)
    x, y = _cnn_batch(rng)
    gemm = Cnn(CnnConfig(algos=("gemm", "gemm", "gemm")))
    fft = Cnn(CnnConfig(algos=("fft", "fft", "fft")))
    p = _params(gemm)  # same init works for both (same specs)
    np.testing.assert_allclose(
        float(gemm.loss(p, x, y)), float(fft.loss(p, x, y)), rtol=1e-4
    )


def test_cnn_metrics_counts():
    cnn = Cnn()
    rng = np.random.default_rng(4)
    x, y = _cnn_batch(rng, n=8)
    loss, correct = cnn.metrics(_params(cnn), x, y)
    assert 0.0 <= float(correct) <= 8.0
    assert float(loss) > 0


def test_cnn_custom_config_geometry():
    cfg = CnnConfig(
        image=16,
        convs=(ConvSpec(8, 3, 1, 1, 2), ConvSpec(16, 3, 1, 1, 2)),
        fc=(32,),
        algos=("gemm", "gemm"),
    )
    cnn = Cnn(cfg)
    assert cfg.out_hw() == 4
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 16, 16, 3)), jnp.float32)
    logits = cnn.logits(_params(cnn), x)
    assert logits.shape == (2, 10)


def test_cnn_algo_arity_checked():
    with pytest.raises(AssertionError):
        Cnn(CnnConfig(algos=("gemm",)))  # 3 convs need 3 algos


# ------------------------------------------------------------------- LM

def test_lm_param_count():
    lm = TransformerLm()
    specs = lm.param_specs()
    assert len(specs) == 2 + 2 * 10 + 3  # embed/pos + 2 blocks + lnf/head
    total = sum(int(np.prod(s)) for _, s in specs)
    assert total == 469_504


def test_lm_loss_starts_near_uniform():
    lm = TransformerLm()
    rng = np.random.default_rng(6)
    x, y = _lm_batch(rng, lm)
    loss = float(lm.loss(_params(lm), x, y))
    np.testing.assert_allclose(loss, np.log(256), rtol=1e-4)


def test_lm_causality():
    """Changing a future token must not affect earlier logits."""
    lm = TransformerLm()
    p = _params(lm)
    rng = np.random.default_rng(7)
    # Zero-init head maps every hidden state to zero logits; randomize it
    # so perturbations are visible.
    p[-1] = jnp.asarray(rng.standard_normal(p[-1].shape), jnp.float32) * 0.1
    x, _ = _lm_batch(rng, lm, n=1)
    base = lm.logits(p, x)
    x2 = x.at[0, -1].set((int(x[0, -1]) + 1) % 256)
    pert = lm.logits(p, x2)
    np.testing.assert_allclose(base[0, :-1], pert[0, :-1], atol=1e-5)
    assert not np.allclose(base[0, -1], pert[0, -1])


def test_lm_grads_finite():
    lm = TransformerLm(LmConfig(n_layers=1))
    rng = np.random.default_rng(8)
    x, y = _lm_batch(rng, lm)
    grads = jax.grad(lambda ps: lm.loss(ps, x, y))(_params(lm))
    for (name, _), g in zip(lm.param_specs(), grads):
        assert np.all(np.isfinite(np.asarray(g))), name


# ---------------------------------------------------------- step builders

@pytest.mark.parametrize("model_f", [Cnn, TransformerLm])
def test_train_step_signature(model_f):
    model = model_f()
    nparams = len(model.param_specs())
    specs = step_specs(model, "train_step", 2)
    assert len(specs) == nparams + 3  # params + x + y + lr
    out = jax.eval_shape(build_train_step(model), *specs)
    assert len(out) == nparams + 1  # params' + loss
    assert out[-1].shape == ()


@pytest.mark.parametrize("kind,extra_in,extra_out", [
    ("grad_step", 2, 1),
    ("eval_step", 2, None),
])
def test_other_step_signatures(kind, extra_in, extra_out):
    model = Cnn()
    nparams = len(model.param_specs())
    specs = step_specs(model, kind, 4)
    assert len(specs) == nparams + extra_in
    fn = {"grad_step": build_grad_step, "eval_step": build_eval_step}[kind](model)
    out = jax.eval_shape(fn, *specs)
    if kind == "eval_step":
        assert len(out) == 2
    else:
        assert len(out) == nparams + 1


def test_train_step_equals_grad_plus_sgd():
    """train_step must equal grad_step + w - lr*g (the rust runtime
    relies on this equivalence to mix local and distributed modes)."""
    model = Cnn()
    p = _params(model)
    rng = np.random.default_rng(9)
    x, y = _cnn_batch(rng)
    lr = jnp.float32(0.05)
    t_out = build_train_step(model)(*p, x, y, lr)
    g_out = build_grad_step(model)(*p, x, y)
    np.testing.assert_allclose(float(t_out[-1]), float(g_out[-1]), rtol=1e-6)
    for pi, ti, gi in zip(p, t_out[:-1], g_out[:-1]):
        np.testing.assert_allclose(
            np.asarray(ti), np.asarray(pi - lr * gi), rtol=1e-4, atol=1e-5
        )


def test_step_specs_rejects_unknown_kind():
    with pytest.raises(ValueError):
        step_specs(Cnn(), "predict_step", 4)
