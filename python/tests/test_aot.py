"""AOT pipeline tests: HLO text validity, sidecar metadata consistency,
and the lowering round-trip for a tiny model (fast — does not re-lower
the full artifact matrix)."""

import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot
from compile.model import Cnn, CnnConfig, build_train_step, step_specs
from compile.models.cnn import ConvSpec


def tiny_cnn():
    return Cnn(CnnConfig(
        image=8,
        convs=(ConvSpec(4, 3, 1, 1, 2),),
        fc=(),
        algos=("gemm",),
    ))


def test_to_hlo_text_roundtrip():
    """Lower a small jitted fn to HLO text; it must parse as HLO and
    contain an entry computation (what HloModuleProto::from_text_file
    consumes on the rust side)."""
    model = tiny_cnn()
    fn = build_train_step(model)
    specs = step_specs(model, "train_step", 2)
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Parameter count visible in the entry signature:
    nparams = len(model.param_specs())
    assert f"parameter({nparams + 2})" in text  # lr is the last input


def test_artifact_matrix_is_consistent():
    """Every ARTIFACTS entry references a defined model and valid kind."""
    models = aot.build_models()
    kinds = {"train_step", "grad_step", "eval_step"}
    names = set()
    for name, model_key, kind, batch in aot.ARTIFACTS:
        assert name not in names, f"duplicate artifact {name}"
        names.add(name)
        assert model_key in models, name
        assert kind in kinds, name
        assert batch >= 1


def test_write_family_blob_layout(tmp_path):
    model = tiny_cnn()
    aot.write_family(str(tmp_path), "tiny", model)
    with open(tmp_path / "tiny.manifest.json") as f:
        manifest = json.load(f)
    specs = model.param_specs()
    assert len(manifest["params"]) == len(specs)
    offset = 0
    for p, (name, shape) in zip(manifest["params"], specs):
        assert p["name"] == name
        assert tuple(p["shape"]) == tuple(shape)
        assert p["offset"] == offset
        offset += p["size"]
    assert manifest["total_elems"] == offset
    blob = np.fromfile(tmp_path / "tiny.init.bin", dtype="<f4")
    assert blob.size == offset
    # First param round-trips exactly.
    init0 = model.init(0)[0].reshape(-1)
    np.testing.assert_array_equal(blob[: init0.size], init0)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/index.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_artifacts_index_valid():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "index.json")) as f:
        index = json.load(f)
    assert len(index["artifacts"]) == len(aot.ARTIFACTS)
    for a in index["artifacts"]:
        hlo = os.path.join(root, a["hlo"])
        assert os.path.exists(hlo), a["name"]
        with open(hlo) as f:
            head = f.read(4096)
        assert "HloModule" in head, a["name"]
        # Calling convention arity:
        if a["kind"] == "train_step":
            assert len(a["inputs"]) == a["num_params"] + 3
            assert len(a["outputs"]) == a["num_params"] + 1
        elif a["kind"] == "grad_step":
            assert len(a["inputs"]) == a["num_params"] + 2
            assert len(a["outputs"]) == a["num_params"] + 1
        else:
            assert len(a["outputs"]) == 2


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/index.json")),
    reason="artifacts not built",
)
def test_built_init_blob_matches_model():
    """The shipped cnn init blob equals a fresh init(seed=0) — rust and
    python agree on initial parameters."""
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    blob = np.fromfile(os.path.join(root, "cnn.init.bin"), dtype="<f4")
    fresh = np.concatenate([a.reshape(-1) for a in Cnn().init(0)])
    np.testing.assert_array_equal(blob, fresh)
