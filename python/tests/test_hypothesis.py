"""Hypothesis sweeps over kernel shapes/dtypes (property-based L1 tests).

Shapes are drawn adversarially around tile boundaries; every draw is
checked against the pure-jnp oracle with assert_allclose.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_pallas, conv2d_gemm, conv2d_fft, sgd_update, layernorm
from compile.kernels import ref

# interpret-mode pallas is slow; keep example counts tight but adversarial.
_SETTINGS = dict(max_examples=20, deadline=None)

dims = st.integers(min_value=1, max_value=160)
small_dims = st.integers(min_value=1, max_value=24)
dtypes = st.sampled_from(["float32", "bfloat16"])


def _mk(rng, shape, dtype="float32"):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32)).astype(dtype)


@settings(**_SETTINGS)
@given(m=dims, k=dims, n=dims, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_matmul_any_shape_dtype(m, k, n, dtype, seed):
    rng = np.random.default_rng(seed)
    x, w = _mk(rng, (m, k), dtype), _mk(rng, (k, n), dtype)
    got = matmul_pallas(x, w)
    want = ref.matmul_ref(x, w)
    assert got.shape == (m, n)
    assert got.dtype == jnp.float32  # MXU accumulate dtype
    tol = 5e-2 if dtype == "bfloat16" else 1e-3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@settings(**_SETTINGS)
@given(
    n=st.integers(1, 3),
    h=st.integers(4, 20),
    c=st.integers(1, 5),
    k=st.integers(1, 6),
    f=st.integers(1, 5),
    stride=st.integers(1, 3),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_gemm_any_geometry(n, h, c, k, f, stride, pad, seed):
    if h + 2 * pad < f:
        return  # filter larger than padded input: not a valid conv
    rng = np.random.default_rng(seed)
    x = _mk(rng, (n, h, h, c))
    w = _mk(rng, (f, f, c, k))
    got = conv2d_gemm(x, w, stride=stride, padding=pad)
    want = ref.conv2d_ref(x, w, stride=stride, padding=pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 2),
    h=st.integers(6, 16),
    c=st.integers(1, 3),
    k=st.integers(1, 4),
    f=st.integers(1, 5),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_fft_any_geometry(n, h, c, k, f, pad, seed):
    if h + 2 * pad < f:
        return
    rng = np.random.default_rng(seed)
    x = _mk(rng, (n, h, h, c))
    w = _mk(rng, (f, f, c, k))
    got = conv2d_fft(x, w, stride=1, padding=pad)
    want = ref.conv2d_ref(x, w, stride=1, padding=pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-3)


@settings(**_SETTINGS)
@given(
    numel=st.integers(1, 200_000),
    lr=st.floats(1e-5, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_any_size(numel, lr, seed):
    rng = np.random.default_rng(seed)
    w = _mk(rng, (numel,))
    g = _mk(rng, (numel,))
    got = sgd_update(w, g, lr)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.sgd_ref(w, g, lr)), rtol=1e-5, atol=1e-5
    )


@settings(**_SETTINGS)
@given(rows=st.integers(1, 64), d=st.integers(2, 300), seed=st.integers(0, 2**31 - 1))
def test_layernorm_any_shape(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = _mk(rng, (rows, d))
    gamma = _mk(rng, (d,))
    beta = _mk(rng, (d,))
    got = layernorm(x, gamma, beta)
    want = ref.layernorm_ref(x, gamma, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)
