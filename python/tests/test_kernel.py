"""L1 kernel vs ref oracle — the CORE correctness signal.

Every Pallas kernel is checked against its pure-jnp oracle, on both the
forward and (where differentiable) backward paths, across shape grids that
exercise padding/tiling boundaries.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.kernels import (
    matmul,
    matmul_pallas,
    conv2d,
    conv2d_gemm,
    conv2d_fft,
    im2col,
    sgd_update,
    momentum_update,
    layernorm,
)
from compile.kernels import ref


def _rng(seed=0):
    return np.random.default_rng(seed)


def _arr(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------- matmul

MATMUL_SHAPES = [
    (1, 1, 1),          # degenerate
    (8, 128, 16),       # exactly one tile
    (128, 128, 128),    # exactly one block
    (129, 130, 131),    # every dim crosses a block boundary by 1
    (37, 53, 29),       # odd, sub-block
    (256, 64, 512),     # multi-block K (accumulation loop)
    (3, 300, 5),        # wide K, skinny M/N
]


@pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
def test_matmul_forward(m, k, n):
    rng = _rng(m * 7 + k * 3 + n)
    x, w = _arr(rng, (m, k)), _arr(rng, (k, n))
    np.testing.assert_allclose(
        matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("m,k,n", [(37, 53, 29), (128, 128, 128), (16, 200, 8)])
def test_matmul_vjp(m, k, n):
    rng = _rng(42)
    x, w = _arr(rng, (m, k)), _arr(rng, (k, n))
    got = jax.grad(lambda a, b: jnp.sum(matmul(a, b) ** 2), argnums=(0, 1))(x, w)
    want = jax.grad(lambda a, b: jnp.sum(jnp.matmul(a, b) ** 2), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-3, atol=1e-3)


def test_matmul_block_override():
    rng = _rng(1)
    x, w = _arr(rng, (64, 96)), _arr(rng, (96, 48))
    out = matmul_pallas(x, w, block_m=32, block_n=32, block_k=32)
    np.testing.assert_allclose(out, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    rng = _rng(2)
    with pytest.raises(ValueError):
        matmul_pallas(_arr(rng, (4, 5)), _arr(rng, (6, 7)))
    with pytest.raises(ValueError):
        matmul_pallas(_arr(rng, (4,)), _arr(rng, (4, 2)))


def test_matmul_zero_inputs():
    x = jnp.zeros((17, 33), jnp.float32)
    w = jnp.zeros((33, 9), jnp.float32)
    np.testing.assert_array_equal(matmul(x, w), jnp.zeros((17, 9)))


# ------------------------------------------------------------------ conv

CONV_CASES = [
    # (n, h, w, c, fh, fw, k, stride, pad)
    (1, 8, 8, 1, 3, 3, 4, 1, 1),
    (2, 16, 16, 3, 5, 5, 8, 1, 2),     # AlexNet-ish same-conv
    (2, 13, 13, 4, 3, 3, 6, 1, 1),     # odd spatial (paper's conv3-5 shape)
    (1, 11, 11, 2, 4, 4, 3, 1, 0),     # even filter, valid
    (2, 16, 16, 3, 5, 5, 8, 2, 2),     # strided
    (1, 28, 28, 3, 11, 11, 8, 4, 2),   # AlexNet conv1 geometry, scaled
]


@pytest.mark.parametrize("algo", ["gemm", "fft"])
@pytest.mark.parametrize("n,h,w,c,fh,fw,k,stride,pad", CONV_CASES)
def test_conv_matches_ref(algo, n, h, w, c, fh, fw, k, stride, pad):
    rng = _rng(n * h + fh * 13 + stride)
    x, wt = _arr(rng, (n, h, w, c)), _arr(rng, (fh, fw, c, k))
    out = conv2d(x, wt, stride=stride, padding=pad, algo=algo)
    want = ref.conv2d_ref(x, wt, stride=stride, padding=pad)
    assert out.shape == want.shape
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)


def test_conv_algos_agree():
    """The ILP may pick either algorithm; numerics must be interchangeable."""
    rng = _rng(7)
    x, wt = _arr(rng, (2, 12, 12, 3)), _arr(rng, (3, 3, 3, 5))
    a = conv2d_gemm(x, wt, stride=1, padding=1)
    b = conv2d_fft(x, wt, stride=1, padding=1)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_conv_gemm_grad():
    rng = _rng(8)
    x, wt = _arr(rng, (1, 8, 8, 2)), _arr(rng, (3, 3, 2, 4))

    def loss_pallas(w_):
        return jnp.sum(conv2d_gemm(x, w_, stride=1, padding=1) ** 2)

    def loss_ref(w_):
        return jnp.sum(ref.conv2d_ref(x, w_, stride=1, padding=1) ** 2)

    np.testing.assert_allclose(
        jax.grad(loss_pallas)(wt), jax.grad(loss_ref)(wt), rtol=1e-3, atol=1e-3
    )


def test_im2col_shape():
    rng = _rng(9)
    x = _arr(rng, (2, 10, 10, 3))
    cols, (n, oh, ow) = im2col(x, 3, 3, 1, 1)
    assert (n, oh, ow) == (2, 10, 10)
    assert cols.shape == (2 * 10 * 10, 3 * 3 * 3)


def test_conv_unknown_algo():
    rng = _rng(10)
    with pytest.raises(ValueError, match="unknown conv algo"):
        conv2d(_arr(rng, (1, 4, 4, 1)), _arr(rng, (3, 3, 1, 1)), algo="winograd9000")


# ------------------------------------------------------------- optimizers

@pytest.mark.parametrize("numel", [1, 127, 128, 129, 32768, 32769, 100_000])
def test_sgd_update(numel):
    rng = _rng(numel)
    w, g = _arr(rng, (numel,)), _arr(rng, (numel,))
    np.testing.assert_allclose(
        sgd_update(w, g, 0.05), ref.sgd_ref(w, g, 0.05), rtol=1e-5, atol=1e-6
    )


def test_sgd_update_nd_shape():
    rng = _rng(3)
    w, g = _arr(rng, (5, 5, 3, 7)), _arr(rng, (5, 5, 3, 7))
    out = sgd_update(w, g, 0.01)
    assert out.shape == w.shape
    np.testing.assert_allclose(out, ref.sgd_ref(w, g, 0.01), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("numel", [63, 4096, 70_001])
def test_momentum_update(numel):
    rng = _rng(numel + 1)
    w, v, g = _arr(rng, (numel,)), _arr(rng, (numel,)), _arr(rng, (numel,))
    mw, mv = momentum_update(w, v, g, 0.1, 0.9)
    rw, rv = ref.momentum_ref(w, v, g, 0.1, 0.9)
    np.testing.assert_allclose(mw, rw, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mv, rv, rtol=1e-5, atol=1e-6)


def test_momentum_zero_mu_is_sgd():
    rng = _rng(4)
    w, v, g = _arr(rng, (500,)), _arr(rng, (500,)), _arr(rng, (500,))
    mw, mv = momentum_update(w, v, g, 0.2, 0.0)
    np.testing.assert_allclose(mw, ref.sgd_ref(w, g, 0.2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mv, g, rtol=1e-6)


# -------------------------------------------------------------- layernorm

@pytest.mark.parametrize("shape", [(4, 64), (3, 5, 100), (2, 7, 128), (1, 129)])
def test_layernorm(shape):
    rng = _rng(shape[-1])
    x = _arr(rng, shape)
    gamma, beta = _arr(rng, (shape[-1],)), _arr(rng, (shape[-1],))
    np.testing.assert_allclose(
        layernorm(x, gamma, beta),
        ref.layernorm_ref(x, gamma, beta),
        rtol=1e-4,
        atol=1e-4,
    )


def test_layernorm_moments():
    """With unit gamma / zero beta, rows are ~zero-mean unit-var."""
    rng = _rng(11)
    x = _arr(rng, (16, 200)) * 3.0 + 2.0
    y = layernorm(x, jnp.ones(200), jnp.zeros(200))
    np.testing.assert_allclose(np.mean(np.asarray(y), axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.var(np.asarray(y), axis=-1), 1.0, atol=1e-3)


def test_layernorm_vjp():
    rng = _rng(12)
    x = _arr(rng, (6, 96))
    gamma, beta = _arr(rng, (96,)), _arr(rng, (96,))
    got = jax.grad(lambda a: jnp.sum(layernorm(a, gamma, beta) ** 2))(x)
    want = jax.grad(lambda a: jnp.sum(ref.layernorm_ref(a, gamma, beta) ** 2))(x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
