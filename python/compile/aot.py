"""AOT pipeline: lower every artifact variant to HLO **text** + sidecar
metadata, into ``artifacts/``.

HLO text (NOT ``lowered.compiler_ir("hlo")``/.serialize()) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the rust `xla`
0.1.6 crate binds) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Outputs per model:
  <model>.manifest.json   parameter names/shapes/offsets (+ config)
  <model>.init.bin        raw little-endian f32 init blob, param order
Outputs per artifact variant:
  <name>.hlo.txt          the lowered step
And one global:
  index.json              all artifacts with shapes and calling convention

Usage: python -m compile.aot --out-dir ../artifacts [--only NAME_SUBSTR]
"""

import argparse
import json
import os
import sys

import numpy as np
import jax
from jax._src.lib import xla_client as xc

from .model import (
    MODELS,
    Cnn,
    CnnConfig,
    TransformerLm,
    LmConfig,
    STEP_BUILDERS,
    step_specs,
)
from .models.cnn import ConvSpec


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ------------------------------------------------------------ model zoo

def build_models():
    """The artifact matrix: models x conv-algo variants x step x batch."""
    cnn_gemm = Cnn(CnnConfig(algos=("gemm", "gemm", "gemm")))
    cnn_fft = Cnn(CnnConfig(algos=("fft", "fft", "fft")))
    # Mixed assignment, as the ILP would produce under a tight M_bound:
    # big first-layer filter -> fft, cheap 3x3 -> gemm.
    cnn_mixed = Cnn(CnnConfig(algos=("fft", "gemm", "gemm")))
    lm = TransformerLm(LmConfig())
    return {
        "cnn": (cnn_gemm, "cnn"),      # (model, manifest/init family)
        "cnn_fft": (cnn_fft, "cnn"),
        "cnn_mixed": (cnn_mixed, "cnn"),
        "lm": (lm, "lm"),
    }


# One entry per artifact: (artifact name, model key, step kind, batch).
ARTIFACTS = [
    ("cnn_gemm_b16_train", "cnn", "train_step", 16),
    ("cnn_gemm_b32_train", "cnn", "train_step", 32),
    ("cnn_gemm_b64_train", "cnn", "train_step", 64),
    ("cnn_gemm_b128_train", "cnn", "train_step", 128),
    ("cnn_fft_b32_train", "cnn_fft", "train_step", 32),
    ("cnn_mixed_b32_train", "cnn_mixed", "train_step", 32),
    ("cnn_gemm_b32_grad", "cnn", "grad_step", 32),
    ("cnn_gemm_b256_eval", "cnn", "eval_step", 256),
    ("lm_b8_train", "lm", "train_step", 8),
    ("lm_b8_grad", "lm", "grad_step", 8),
    ("lm_b32_eval", "lm", "eval_step", 32),
]


def _spec_json(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def write_family(out_dir: str, family: str, model) -> None:
    """Write <family>.manifest.json + <family>.init.bin once per family."""
    specs = model.param_specs()
    init = model.init(seed=0)
    offset = 0
    params = []
    for (name, shape), arr in zip(specs, init):
        assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
        size = int(np.prod(shape)) if shape else 1
        params.append(
            {"name": name, "shape": list(shape), "size": size, "offset": offset}
        )
        offset += size
    manifest = {
        "family": family,
        "params": params,
        "total_elems": offset,
        "config": {k: v for k, v in vars(model.cfg).items() if _jsonable(v)},
    }
    with open(os.path.join(out_dir, f"{family}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    blob = np.concatenate([a.reshape(-1).astype("<f4") for a in init])
    assert blob.size == offset
    blob.tofile(os.path.join(out_dir, f"{family}.init.bin"))
    print(f"  {family}: {len(params)} params, {offset} elems "
          f"({offset * 4 / 1e6:.1f} MB init blob)")


def _jsonable(v):
    if isinstance(v, (int, float, str, bool)) or v is None:
        return True
    if isinstance(v, (list, tuple)):
        return all(_jsonable(x) for x in v)
    return False


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    models = build_models()
    families_written = set()
    index = {"convention": {
        "train_step": "(params..., x, y, lr) -> (params'..., loss)",
        "grad_step": "(params..., x, y) -> (grads..., loss)",
        "eval_step": "(params..., x, y) -> (loss, correct)",
    }, "artifacts": []}

    for name, model_key, kind, batch in ARTIFACTS:
        if args.only and args.only not in name:
            continue
        model, family = models[model_key]
        if family not in families_written:
            write_family(args.out_dir, family, model)
            families_written.add(family)

        specs = step_specs(model, kind, batch)
        fn = STEP_BUILDERS[kind](model)
        print(f"  lowering {name} ({kind}, batch={batch}) ...", flush=True)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        hlo_path = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, hlo_path), "w") as f:
            f.write(text)

        out_tree = jax.eval_shape(fn, *specs)
        index["artifacts"].append({
            "name": name,
            "model": model_key,
            "family": family,
            "kind": kind,
            "batch": batch,
            "hlo": hlo_path,
            "num_params": len(model.param_specs()),
            "inputs": [_spec_json(s) for s in specs],
            "outputs": [_spec_json(s) for s in out_tree],
        })
        print(f"    -> {hlo_path} ({len(text)/1e6:.2f} MB hlo text)")

    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"wrote {len(index['artifacts'])} artifacts to {args.out_dir}/index.json")


if __name__ == "__main__":
    sys.exit(main())
