"""Decoder-only transformer LM — the e2e-validation workload.

Byte-level language model: pre-LN blocks of causal self-attention + MLP.
All linear algebra routes through the L1 Pallas tiled-matmul kernel and
the Pallas layernorm kernel, so the fwd+bwd train step lowers into one
HLO module dominated by the MXU-tiled GEMM.

The paper predates transformers; we use one because the repro mandate
requires an end-to-end LM training driver.  The configuration below is
CPU-feasible (the paper's 60M-param AlexNet / "100M-scale" regime is not
trainable for hundreds of steps on one CPU core — see DESIGN.md §4); the
config scales to arbitrary width/depth for lowering-only studies.
"""

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..kernels import matmul, layernorm


@dataclass(frozen=True)
class LmConfig:
    vocab: int = 256
    seq: int = 64
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


def _mm(x: jax.Array, w: jax.Array) -> jax.Array:
    """Batched (.., d_in) @ (d_in, d_out) through the 2-D Pallas kernel."""
    lead = x.shape[:-1]
    rows = 1
    for s in lead:
        rows *= s
    return matmul(x.reshape(rows, x.shape[-1]), w).reshape(*lead, w.shape[-1])


class TransformerLm:
    name = "lm"

    def __init__(self, cfg: LmConfig = LmConfig()):
        assert cfg.d_model % cfg.n_heads == 0
        self.cfg = cfg

    # ------------------------------------------------------------ params

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        cfg = self.cfg
        specs = [("embed", (cfg.vocab, cfg.d_model)), ("pos", (cfg.seq, cfg.d_model))]
        for i in range(cfg.n_layers):
            p = f"block{i}."
            specs += [
                (p + "ln1.g", (cfg.d_model,)),
                (p + "ln1.b", (cfg.d_model,)),
                (p + "attn.wqkv", (cfg.d_model, 3 * cfg.d_model)),
                (p + "attn.wo", (cfg.d_model, cfg.d_model)),
                (p + "ln2.g", (cfg.d_model,)),
                (p + "ln2.b", (cfg.d_model,)),
                (p + "mlp.w1", (cfg.d_model, cfg.d_ff)),
                (p + "mlp.b1", (cfg.d_ff,)),
                (p + "mlp.w2", (cfg.d_ff, cfg.d_model)),
                (p + "mlp.b2", (cfg.d_model,)),
            ]
        specs += [
            ("lnf.g", (cfg.d_model,)),
            ("lnf.b", (cfg.d_model,)),
            ("head", (cfg.d_model, cfg.vocab)),
        ]
        return specs

    def init(self, seed: int = 0) -> List[np.ndarray]:
        rng = np.random.default_rng(seed)
        out = []
        for name, shape in self.param_specs():
            if name.endswith(".g"):
                out.append(np.ones(shape, np.float32))  # layernorm gain
            elif len(shape) == 1 or name == "head":
                # biases / ln shift / zero-init head (loss starts at
                # exactly ln(vocab), stabilizing early SGD — as the CNN).
                out.append(np.zeros(shape, np.float32))
            else:
                scale = 0.02 if name in ("embed", "pos") else np.sqrt(1.0 / shape[0])
                out.append((rng.standard_normal(shape) * scale).astype(np.float32))
        return out

    # ----------------------------------------------------------- forward

    def logits(self, params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
        cfg = self.cfg
        it = iter(params)
        embed, pos = next(it), next(it)
        b, t = x.shape
        h = embed[x] + pos[None, :t, :]

        mask = jnp.tril(jnp.ones((t, t), jnp.float32))
        neg = jnp.float32(-1e9)

        for _ in range(cfg.n_layers):
            ln1g, ln1b = next(it), next(it)
            wqkv, wo = next(it), next(it)
            ln2g, ln2b = next(it), next(it)
            w1, b1, w2, b2 = next(it), next(it), next(it), next(it)

            # -- causal self-attention (pre-LN)
            hn = layernorm(h, ln1g, ln1b)
            qkv = _mm(hn, wqkv)  # (b, t, 3d)
            q, k, v = jnp.split(qkv, 3, axis=-1)

            def heads(z):
                return z.reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

            q, k, v = heads(q), heads(k), heads(v)
            att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(cfg.d_head)
            att = jnp.where(mask[None, None] > 0, att, neg)
            att = jax.nn.softmax(att, axis=-1)
            ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
            ctx = ctx.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
            h = h + _mm(ctx, wo)

            # -- MLP
            hn = layernorm(h, ln2g, ln2b)
            h = h + _mm(jax.nn.gelu(_mm(hn, w1) + b1), w2) + b2

        lnfg, lnfb = next(it), next(it)
        head = next(it)
        return _mm(layernorm(h, lnfg, lnfb), head)

    def loss(self, params, x, y) -> jax.Array:
        logits = self.logits(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

    def metrics(self, params, x, y):
        logits = self.logits(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, correct

    # --------------------------------------------------------------- AOT

    def input_specs(self, batch: int):
        cfg = self.cfg
        return (
            jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32),
            jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32),
        )
