"""AlexNet-lite CNN — the paper's CNN workload, scaled to the synthetic
32x32 image task (DESIGN.md §4: ImageNet -> synthetic substitution).

The architecture follows AlexNet's shape grammar (§3.1.3: stacked
conv[+pool] feature extraction, then fully-connected classification) so
the paper's memory model (Eqs. 2-5) applies layer-by-layer.  Every conv
layer takes a per-layer algorithm choice ("gemm" | "fft") — the knob the
advisor's ILP (Eq. 6) optimizes.  All matmuls/convs run on the L1 Pallas
kernels; the FFT path is the L2 jnp.fft alternative.
"""

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..kernels import conv2d, matmul


@dataclass(frozen=True)
class ConvSpec:
    """One feature-extraction layer (paper Eq. 1 geometry)."""

    filters: int      # K_i
    size: int         # F_i
    stride: int       # S_i
    pad: int          # P_i
    pool: int         # max-pool window/stride after the conv (0 = none)


@dataclass(frozen=True)
class CnnConfig:
    image: int = 32
    channels: int = 3
    classes: int = 10
    convs: Tuple[ConvSpec, ...] = (
        ConvSpec(32, 5, 1, 2, 2),
        ConvSpec(64, 5, 1, 2, 2),
        ConvSpec(128, 3, 1, 1, 2),
    )
    fc: Tuple[int, ...] = (256,)
    # Per-conv-layer algorithm, chosen by the L3 advisor ILP.
    algos: Tuple[str, ...] = ("gemm", "gemm", "gemm")

    def out_hw(self) -> int:
        hw = self.image
        for c in self.convs:
            hw = (hw - c.size + 2 * c.pad) // c.stride + 1
            if c.pool:
                hw //= c.pool
        return hw


class Cnn:
    name = "cnn"

    def __init__(self, cfg: CnnConfig = CnnConfig()):
        assert len(cfg.algos) == len(cfg.convs), "one algo per conv layer"
        self.cfg = cfg

    # ------------------------------------------------------------ params

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        cfg = self.cfg
        specs = []
        cin = cfg.channels
        for i, c in enumerate(cfg.convs):
            specs.append((f"conv{i}.w", (c.size, c.size, cin, c.filters)))
            specs.append((f"conv{i}.b", (c.filters,)))
            cin = c.filters
        dim = cfg.out_hw() ** 2 * cin
        for j, width in enumerate(cfg.fc):
            specs.append((f"fc{j}.w", (dim, width)))
            specs.append((f"fc{j}.b", (width,)))
            dim = width
        specs.append(("head.w", (dim, cfg.classes)))
        specs.append(("head.b", (cfg.classes,)))
        return specs

    def init(self, seed: int = 0) -> List[np.ndarray]:
        rng = np.random.default_rng(seed)
        out = []
        for name, shape in self.param_specs():
            if name.endswith(".b") or name == "head.w":
                # zero-init the classifier head: initial loss = ln(classes),
                # keeps early SGD steps stable at practical learning rates.
                out.append(np.zeros(shape, np.float32))
            else:
                fan_in = int(np.prod(shape[:-1]))
                scale = np.sqrt(2.0 / fan_in)  # He init (ReLU network)
                out.append((rng.standard_normal(shape) * scale).astype(np.float32))
        return out

    # ----------------------------------------------------------- forward

    def logits(self, params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
        cfg = self.cfg
        p = list(params)
        h = x
        for i, c in enumerate(cfg.convs):
            w, b = p[2 * i], p[2 * i + 1]
            h = conv2d(h, w, stride=c.stride, padding=c.pad, algo=cfg.algos[i])
            h = jax.nn.relu(h + b)
            if c.pool:
                h = jax.lax.reduce_window(
                    h,
                    -jnp.inf,
                    jax.lax.max,
                    (1, c.pool, c.pool, 1),
                    (1, c.pool, c.pool, 1),
                    "VALID",
                )
        n = h.shape[0]
        h = h.reshape(n, -1)
        base = 2 * len(cfg.convs)
        for j in range(len(cfg.fc)):
            w, b = p[base + 2 * j], p[base + 2 * j + 1]
            h = jax.nn.relu(matmul(h, w) + b)
        w, b = p[-2], p[-1]
        return matmul(h, w) + b

    def loss(self, params, x, y) -> jax.Array:
        logits = self.logits(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))

    def metrics(self, params, x, y):
        """(mean loss, top-1 correct count).  The paper plots top-5 error on
        1000 classes (Fig. 3); with 10 synthetic classes top-1 is the analog."""
        logits = self.logits(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, correct

    # --------------------------------------------------------------- AOT

    def input_specs(self, batch: int):
        cfg = self.cfg
        return (
            jax.ShapeDtypeStruct((batch, cfg.image, cfg.image, cfg.channels), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        )
