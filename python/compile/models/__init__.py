"""L2 model zoo: the compute graphs the coordinator trains.

Each model exposes:
  param_specs() -> [(name, shape), ...]       (deterministic order)
  init(seed)    -> [np.ndarray, ...]          (matching param_specs)
  loss(params, x, y) -> scalar mean loss
  metrics(params, x, y) -> (loss, correct)    (evaluation path)
plus input_specs(batch) for AOT lowering.
"""

from .cnn import CnnConfig, Cnn
from .transformer import LmConfig, TransformerLm

MODELS = {"cnn": Cnn, "lm": TransformerLm}

__all__ = ["CnnConfig", "Cnn", "LmConfig", "TransformerLm", "MODELS"]
