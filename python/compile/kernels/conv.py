"""Convolution algorithms — the paper's §3.1.2 speed/memory trade-off.

Two interchangeable implementations of NHWC conv2d, mirroring the paper's
cuDNN GEMM-vs-FFT choice (Table 2, Figure 2):

* ``conv2d_gemm`` — im2col lowering into the L1 Pallas tiled-matmul kernel
  (the "GEMM-based" algorithm [10]).  Less memory, slower on large
  filters.
* ``conv2d_fft``  — FFT-domain convolution (the "FFT-based" algorithm
  [37]): pad filters to input size, pointwise multiply in the frequency
  domain.  Faster for large filters, memory-hungry — exactly the Table 2
  ratio the advisor's ILP trades off.

Both produce identical numerics (pytest checks them against
``ref.conv2d_ref`` and each other), so the rust coordinator can switch
artifacts per the ILP solution without affecting convergence.
"""

import jax
import jax.numpy as jnp

from .matmul import matmul


def _out_dim(size: int, f: int, stride: int, pad: int) -> int:
    # Paper Eq. (1): B_{i+1} = (B_i - F + 2P)/S + 1
    return (size - f + 2 * pad) // stride + 1


def im2col(x: jax.Array, fh: int, fw: int, stride: int, padding: int) -> jax.Array:
    """NHWC -> (N*OH*OW, FH*FW*C) patch matrix (the "lowering" of [23])."""
    n, h, w, c = x.shape
    oh = _out_dim(h, fh, stride, padding)
    ow = _out_dim(w, fw, stride, padding)
    # conv_general_dilated_patches yields NCHW-grouped patches; dimension
    # numbers keep us in NHWC, feature dim = C*FH*FW ordered (c, fh, fw).
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(fh, fw),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (N, OH, OW, C*FH*FW)
    return patches.reshape(n * oh * ow, c * fh * fw), (n, oh, ow)


def conv2d_gemm(x: jax.Array, w: jax.Array, *, stride: int = 1, padding: int = 0) -> jax.Array:
    """GEMM-based conv: im2col + Pallas tiled matmul.  NHWC x HWIO -> NHWC."""
    fh, fw, c, k = w.shape
    cols, (n, oh, ow) = im2col(x, fh, fw, stride, padding)
    # Patch feature order is (c, fh, fw); reorder the filter to match.
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(c * fh * fw, k)
    out = matmul(cols, wmat)
    return out.reshape(n, oh, ow, k)


def conv2d_fft(x: jax.Array, w: jax.Array, *, stride: int = 1, padding: int = 0) -> jax.Array:
    """FFT-based conv (Mathieu et al. [37]).

    Zero-pads input by `padding`, pads the filter to the padded-input
    spatial size (this is the memory blow-up of Table 2), multiplies in
    the rfft2 domain, and samples the valid/strided output grid.
    Cross-correlation semantics to match cuDNN/`conv2d_ref`.
    """
    n, h, wd, c = x.shape
    fh, fw, c2, k = w.shape
    assert c == c2, (x.shape, w.shape)
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    hp, wp = h + 2 * padding, wd + 2 * padding

    # Frequency-domain cross-correlation: conj(fft(filter)) * fft(input).
    # Filter is zero-padded to (hp, wp) — the FFT memory cost.
    fx = jnp.fft.rfft2(xp.astype(jnp.float32), axes=(1, 2))          # (N, hp, wf, C)
    wpad = jnp.pad(w.astype(jnp.float32), ((0, hp - fh), (0, wp - fw), (0, 0), (0, 0)))
    fw_ = jnp.conj(jnp.fft.rfft2(wpad, axes=(0, 1)))                  # (hp, wf, C, K)
    prod = jnp.einsum("nhwc,hwck->nhwk", fx, fw_)
    full = jnp.fft.irfft2(prod, s=(hp, wp), axes=(1, 2))              # (N, hp, wp, K)

    oh = _out_dim(h, fh, stride, padding)
    ow = _out_dim(wd, fw, stride, padding)
    return full[:, : oh * stride : stride, : ow * stride : stride, :]


CONV_ALGOS = {"gemm": conv2d_gemm, "fft": conv2d_fft}


def conv2d(x, w, *, stride=1, padding=0, algo: str = "gemm"):
    """Algorithm-dispatched conv2d; `algo` is chosen by the L3 advisor ILP."""
    try:
        fn = CONV_ALGOS[algo]
    except KeyError:
        raise ValueError(f"unknown conv algo {algo!r}; have {sorted(CONV_ALGOS)}") from None
    return fn(x, w, stride=stride, padding=padding)
