"""L1 Pallas tiled matmul kernel — the GEMM substrate of the paper.

The paper's convolution hot path is "GEMM-based" (cuDNN im2col + SGEMM on
K80 SMs).  The TPU adaptation (DESIGN.md §Hardware-Adaptation) tiles the
matmul for the 128x128 MXU systolic array instead of CUDA threadblocks:
BlockSpec expresses the HBM->VMEM schedule, block shapes are kept to
multiples of the (8, 128) f32 tile, and accumulation is f32
(`preferred_element_type`), the MXU-native contraction.

Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness target
and the TPU schedule is an estimate (DESIGN.md §8).

Reverse-mode AD does not trace through ``pallas_call``; ``matmul`` is
wrapped in ``jax.custom_vjp`` whose backward pass re-uses the same kernel
on transposed operands, so the entire train-step (fwd+bwd) lowers into one
HLO module built from this kernel.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly tile floor: the f32 native tile is (8, 128); on a real
# TPU 128-512 blocks keep the systolic array busy within the ~16 MiB
# VMEM budget. Under interpret=True on CPU-PJRT, however, each grid
# step's dynamic-update-slice copies the whole output buffer (XLA CPU
# does not make the loop carry in-place), so execution cost is
# grid_steps x M x N — we therefore pick blocks ADAPTIVELY to bound the
# grid to ~8 steps per dimension (EXPERIMENTS.md §Perf: 18-45x step-time
# reduction at M=65k). Explicit block_* overrides restore the TPU-shaped
# schedule for the DESIGN.md §8 estimates.
DEFAULT_BLOCK_M = None  # adaptive
DEFAULT_BLOCK_N = None
DEFAULT_BLOCK_K = None

_MIN_BLOCK_M = 128
_MAX_BLOCK_M = 32768
_MAX_BLOCK_NK = 32768
_TARGET_GRID = 8


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _adaptive_block(dim: int, tile: int, lo: int, hi: int) -> int:
    """Smallest tile-multiple block that keeps grid_steps <= _TARGET_GRID,
    clamped to [lo, hi]."""
    want = _ceil_to((dim + _TARGET_GRID - 1) // _TARGET_GRID, tile)
    return max(lo, min(hi, want))


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """Grid = (M/bm, N/bn, K/bk), K innermost: sequential accumulation."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int | None = DEFAULT_BLOCK_M,
    block_n: int | None = DEFAULT_BLOCK_N,
    block_k: int | None = DEFAULT_BLOCK_K,
) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N) via the tiled Pallas kernel.

    Operands are zero-padded up to block multiples (zeros do not change
    the contraction), the kernel runs over the padded grid, and the
    result is sliced back.  Output dtype is f32 (MXU accumulate dtype).
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul_pallas expects 2-D operands, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")

    if block_m is None:
        block_m = _adaptive_block(m, 8, _MIN_BLOCK_M, _MAX_BLOCK_M)
    if block_n is None:
        block_n = _adaptive_block(n, 128, 128, _MAX_BLOCK_NK)
    if block_k is None:
        block_k = _adaptive_block(k, 128, 128, _MAX_BLOCK_NK)
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 128))
    bk = min(block_k, _ceil_to(k, 128))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w

    nk = kp // bk
    out = pl.pallas_call(
        partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable tiled matmul; fwd and bwd both run the Pallas kernel."""
    return matmul_pallas(x, w)


def _matmul_fwd(x, w):
    return matmul_pallas(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    # dL/dx = g @ w^T, dL/dw = x^T @ g — same kernel, transposed operands.
    dx = matmul_pallas(g, w.T).astype(x.dtype)
    dw = matmul_pallas(x.T, g).astype(w.dtype)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
