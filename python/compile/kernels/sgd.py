"""L1 fused optimizer-update kernels (paper Fig. 1 step 6, "parameter
update").

The update is elementwise, so the kernel tiles a flattened parameter
vector through VMEM in (8, 128)-aligned rows: one HBM read of (w, g[, v])
and one write per element — the bandwidth-bound roofline for this step.
Fusing `w - lr*(mu*v + g)` avoids materializing the intermediate velocity
in HBM, which is the whole point of a fused update.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of 128 f32 lanes; 256 rows * 128 lanes * 4 B = 128 KiB per operand
# block in VMEM — comfortably under budget with three operands resident.
_LANES = 128
_BLOCK_ROWS = 256


def _pad_to_grid(flat: jax.Array):
    n = flat.shape[0]
    per_block = _LANES * _BLOCK_ROWS
    nb = max(1, (n + per_block - 1) // per_block)
    padded = nb * per_block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(nb * _BLOCK_ROWS, _LANES), nb


def _sgd_kernel(lr_ref, w_ref, g_ref, o_ref):
    o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(w: jax.Array, g: jax.Array, lr) -> jax.Array:
    """w <- w - lr * g, tiled through VMEM.  Any shape; returns w's shape."""
    shape = w.shape
    lr = jnp.asarray(lr, jnp.float32).reshape(1)
    flat, nb = _pad_to_grid(w.reshape(-1).astype(jnp.float32))
    gflat, _ = _pad_to_grid(g.reshape(-1).astype(jnp.float32))
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # lr broadcast to every block
            pl.BlockSpec((_BLOCK_ROWS, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((_BLOCK_ROWS, _LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(lr, flat, gflat)
    n = 1
    for d in shape:
        n *= d
    return out.reshape(-1)[:n].reshape(shape)


def _momentum_kernel(hp_ref, w_ref, v_ref, g_ref, ow_ref, ov_ref):
    v2 = hp_ref[1] * v_ref[...] + g_ref[...]
    ov_ref[...] = v2
    ow_ref[...] = w_ref[...] - hp_ref[0] * v2


def momentum_update(w: jax.Array, v: jax.Array, g: jax.Array, lr, mu):
    """Polyak momentum [41]: v <- mu*v + g; w <- w - lr*v.  Fused, tiled."""
    shape = w.shape
    hp = jnp.stack([jnp.asarray(lr, jnp.float32), jnp.asarray(mu, jnp.float32)])
    flat, nb = _pad_to_grid(w.reshape(-1).astype(jnp.float32))
    vflat, _ = _pad_to_grid(v.reshape(-1).astype(jnp.float32))
    gflat, _ = _pad_to_grid(g.reshape(-1).astype(jnp.float32))
    ow, ov = pl.pallas_call(
        _momentum_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((_BLOCK_ROWS, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((_BLOCK_ROWS, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((_BLOCK_ROWS, _LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((_BLOCK_ROWS, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((_BLOCK_ROWS, _LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(flat.shape, jnp.float32),
            jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        ],
        interpret=True,
    )(hp, flat, vflat, gflat)
    n = 1
    for d in shape:
        n *= d
    return (
        ow.reshape(-1)[:n].reshape(shape),
        ov.reshape(-1)[:n].reshape(shape),
    )
