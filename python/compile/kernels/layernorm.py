"""L1 Pallas layer-norm kernel (transformer block normalization).

Row-blocked: each grid step normalizes a (rows, d) tile entirely in VMEM —
one pass computes mean/variance with VPU reductions, then scales.  d is
padded to the 128-lane boundary with a mask so padded lanes do not
perturb the moments.

Differentiable via custom_vjp with an analytic backward (also plain jnp —
the backward is bandwidth-trivial compared to the matmuls around it).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
_BLOCK_ROWS = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _ln_kernel(x_ref, gamma_ref, beta_ref, o_ref, *, d: int, eps: float):
    x = x_ref[...]
    dp = x.shape[-1]
    if dp != d:
        mask = (jax.lax.iota(jnp.int32, dp) < d)[None, :]
        x = jnp.where(mask, x, 0.0)
    else:
        mask = None
    mean = jnp.sum(x, axis=-1, keepdims=True) / d
    if mask is not None:
        cx = jnp.where(mask, x - mean, 0.0)
    else:
        cx = x - mean
    var = jnp.sum(cx * cx, axis=-1, keepdims=True) / d
    y = cx * jax.lax.rsqrt(var + eps) * gamma_ref[...] + beta_ref[...]
    if mask is not None:
        y = jnp.where(mask, y, 0.0)
    o_ref[...] = y


def layernorm_pallas(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    """(..., d) layer norm over the last axis via the Pallas kernel."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d).astype(jnp.float32)

    dp = _ceil_to(d, _LANES)
    br = min(_BLOCK_ROWS, _ceil_to(rows, 8))
    rp = _ceil_to(rows, br)
    if (rp, dp) != (rows, d):
        x2 = jnp.pad(x2, ((0, rp - rows), (0, dp - d)))
    gp = jnp.pad(gamma.astype(jnp.float32), (0, dp - d)).reshape(1, dp)
    bp = jnp.pad(beta.astype(jnp.float32), (0, dp - d)).reshape(1, dp)

    out = pl.pallas_call(
        partial(_ln_kernel, d=d, eps=eps),
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, dp), jnp.float32),
        interpret=True,
    )(x2, gp, bp)
    return out[:rows, :d].reshape(shape)


@jax.custom_vjp
def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array) -> jax.Array:
    return layernorm_pallas(x, gamma, beta)


def _ln_fwd(x, gamma, beta):
    return layernorm_pallas(x, gamma, beta), (x, gamma)


def _ln_bwd(res, g):
    x, gamma = res
    eps = 1e-5
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (x - mean) * inv
    d = x.shape[-1]
    dgamma = jnp.sum(g * xhat, axis=tuple(range(x.ndim - 1)))
    dbeta = jnp.sum(g, axis=tuple(range(x.ndim - 1)))
    gg = g * gamma
    dx = inv * (gg - jnp.mean(gg, axis=-1, keepdims=True)
                - xhat * jnp.mean(gg * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype), dbeta.astype(gamma.dtype)


layernorm.defvjp(_ln_fwd, _ln_bwd)
