"""Pure-jnp correctness oracles for every Pallas kernel.

pytest asserts allclose(kernel, ref) — this is the core L1 correctness
signal (no Pallas, no custom_vjp: plain jnp/lax only).
"""

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1, padding: int = 0) -> jax.Array:
    """NHWC x HWIO -> NHWC convolution (cross-correlation, like cuDNN)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def sgd_ref(w: jax.Array, g: jax.Array, lr) -> jax.Array:
    return w - lr * g


def momentum_ref(w: jax.Array, v: jax.Array, g: jax.Array, lr, mu):
    v2 = mu * v + g
    return w - lr * v2, v2


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
