"""L1: Pallas kernels for the paper's compute hot-spots.

- matmul: MXU-tiled GEMM (the paper's "GEMM-based" substrate), custom-vjp
- conv: im2col-GEMM and FFT conv2d — the §3.1.2 algorithm choice
- sgd: fused SGD / momentum parameter-update kernels (Fig. 1 step 6)
- layernorm: row-blocked normalization for the transformer model
- ref: pure-jnp oracles for all of the above
"""

from .matmul import matmul, matmul_pallas
from .conv import conv2d, conv2d_gemm, conv2d_fft, im2col, CONV_ALGOS
from .sgd import sgd_update, momentum_update
from .layernorm import layernorm, layernorm_pallas

__all__ = [
    "matmul", "matmul_pallas",
    "conv2d", "conv2d_gemm", "conv2d_fft", "im2col", "CONV_ALGOS",
    "sgd_update", "momentum_update",
    "layernorm", "layernorm_pallas",
]
