"""L2 step builders: turn a model into the flat-signature jax functions
that AOT-lower to the HLO artifacts the rust runtime executes.

Artifact calling conventions (the rust side mirrors these in
``rust/src/runtime/artifact.rs``):

  train_step : (p_0..p_{K-1}, x, y, lr)  -> (p'_0..p'_{K-1}, loss)
  grad_step  : (p_0..p_{K-1}, x, y)      -> (g_0..g_{K-1}, loss)
  eval_step  : (p_0..p_{K-1}, x, y)      -> (loss, correct)

Parameters travel as K separate arrays in ``param_specs()`` order — the
parameter-server shards them by index.  The fused SGD update inside
train_step runs on the L1 Pallas update kernel (Fig. 1 step 6).
"""

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import sgd_update
from .models import MODELS, Cnn, CnnConfig, TransformerLm, LmConfig  # re-export


def build_train_step(model) -> Callable:
    """fwd + bwd + fused Pallas SGD update, one jittable function."""
    nparams = len(model.param_specs())

    def train_step(*args):
        params, (x, y, lr) = args[:nparams], args[nparams:]
        loss, grads = jax.value_and_grad(
            lambda ps: model.loss(ps, x, y), argnums=0
        )(list(params))
        new = [sgd_update(p, g, lr) for p, g in zip(params, grads)]
        return (*new, loss)

    return train_step


def build_grad_step(model) -> Callable:
    """fwd + bwd only — workers push these gradients to parameter servers."""
    nparams = len(model.param_specs())

    def grad_step(*args):
        params, (x, y) = args[:nparams], args[nparams:]
        loss, grads = jax.value_and_grad(
            lambda ps: model.loss(ps, x, y), argnums=0
        )(list(params))
        return (*[g.astype(jnp.float32) for g in grads], loss)

    return grad_step


def build_eval_step(model) -> Callable:
    nparams = len(model.param_specs())

    def eval_step(*args):
        params, (x, y) = args[:nparams], args[nparams:]
        loss, correct = model.metrics(list(params), x, y)
        return loss, correct

    return eval_step


STEP_BUILDERS = {
    "train_step": build_train_step,
    "grad_step": build_grad_step,
    "eval_step": build_eval_step,
}


def step_specs(model, kind: str, batch: int) -> Sequence[jax.ShapeDtypeStruct]:
    """Input ShapeDtypeStructs for AOT-lowering `kind` at `batch`."""
    param_in = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in model.param_specs()]
    x, y = model.input_specs(batch)
    if kind == "train_step":
        return [*param_in, x, y, jax.ShapeDtypeStruct((), jnp.float32)]
    if kind in ("grad_step", "eval_step"):
        return [*param_in, x, y]
    raise ValueError(f"unknown step kind {kind!r}")
